"""Shared benchmark utilities: wall-clock timing of jitted callables and the
layer-shape inventories of the paper's five networks."""

from __future__ import annotations

import math
import subprocess
import time
from typing import Callable

import jax
import numpy as np


def bench_metadata() -> dict:
    """Environment stamp for emitted BENCH_*.json artifacts: jax version,
    backend/device kind, git SHA and a timestamp, so the perf trajectory is
    comparable across runs and machines."""
    try:
        sha = subprocess.run(["git", "rev-parse", "HEAD"],
                             capture_output=True, text=True,
                             timeout=10).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        sha = "unknown"
    dev = jax.devices()[0]
    return {"jax_version": jax.__version__,
            "backend": jax.default_backend(),
            "device_kind": getattr(dev, "device_kind", str(dev)),
            "device_count": jax.device_count(),
            "git_sha": sha,
            "timestamp_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ",
                                           time.gmtime())}


def time_jitted(fn: Callable, *args, warmup: int = 2, iters: int = 5,
                inner: int = 1) -> float:
    """Median wall-time (seconds) of fn(*args) after jit warmup."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        for _ in range(inner):
            out = fn(*args)
        jax.block_until_ready(out)
        times.append((time.perf_counter() - t0) / inner)
    return float(np.median(times))


def pairwise_min_times(fa: Callable, fb: Callable, x, warmup: int = 2,
                       iters: int = 5) -> tuple[float, float]:
    """Interleaved best-of timing of two callables on the same input.

    Interleaving cancels slow drift (thermal / co-tenant noise) that makes
    back-to-back medians unreliable; min is the steady-state floor."""
    for _ in range(warmup):
        jax.block_until_ready(fa(x))
        jax.block_until_ready(fb(x))
    ta, tb = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fa(x))
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fb(x))
        tb.append(time.perf_counter() - t0)
    return min(ta), min(tb)


#: storage bytes per element of each transform-domain compute dtype --
#: feeds the filter_elem_bytes parameter of the HBM-bytes models below, so
#: the paper's figure of merit (bytes moved on a bandwidth-bound mobile
#: CPU) reflects bf16/int8 filter payloads.
COMPUTE_DTYPE_BYTES = {"float32": 4, "bfloat16": 2, "int8": 1}


def dtype_bytes(compute_dtype: str) -> int:
    """Storage bytes per element of a transform-domain compute dtype."""
    return COMPUTE_DTYPE_BYTES[str(compute_dtype)]


def streamed_hbm_bytes(spec, batch: int = 1, elem_bytes: int = 4,
                       filter_elem_bytes: int | None = None) -> int:
    """Analytic HBM bytes moved per call by the streaming Winograd executor
    (kernels.winograd.winograd_streamed): halo strip reads (each strip is
    DMA'd once per (M sweep, C block) because the input block index carries
    the channel slice, and adjacent strips re-read their k-1 halo rows/cols)
    + filter block reads (re-fetched per strip) + NHWC output write. No tile
    tensor, no separate epilogue round trips. `elem_bytes` is the
    activation element size (fp32 default); `filter_elem_bytes` the
    transform-domain filter element size (defaults to elem_bytes; pass
    dtype_bytes(compute_dtype) for bf16/int8 plans -- their O(M) dequant
    scale rows are ignored, O(P*C*M) filter traffic dominates). The full
    derivation is in EXPERIMENTS.md section Perf."""
    if filter_elem_bytes is None:
        filter_elem_bytes = elem_bytes
    s = spec.stream
    th, tw = spec.ct_h.t, spec.ct_w.t
    mh, mw = spec.ct_h.m, spec.ct_w.m
    p = th * tw
    hs = s.bh * mh + th - mh
    ws = s.bw * mw + tw - mw
    n_strips = batch * s.n_hb * s.n_wb
    n_mb = s.m_pad // s.block_m
    read_x = n_strips * hs * ws * s.c_pad * n_mb * elem_bytes
    read_u = n_strips * p * s.c_pad * s.m_pad * filter_elem_bytes
    write_y = batch * (s.n_hb * s.bh * mh) * (s.n_wb * s.bw * mw) \
        * s.m_pad * elem_bytes
    return read_x + read_u + write_y


def materialized_hbm_bytes(spec, batch: int = 1, elem_bytes: int = 4,
                           filter_elem_bytes: int | None = None) -> int:
    """Analytic HBM bytes moved per call by the pre-streaming executor
    (ops.winograd_conv2d_planned_materialized + XLA epilogue): padded input
    read, (R, th, tw, C) tile tensor write + per-M-block re-read, filter
    reads, kernel output write, un-tiling read+write, and the bias+relu
    round trips. Element sizes as in streamed_hbm_bytes; see EXPERIMENTS.md
    section Perf."""
    if filter_elem_bytes is None:
        filter_elem_bytes = elem_bytes
    g = spec.geometry
    br, bc, bm = spec.blocks
    th, tw = spec.ct_h.t, spec.ct_w.t
    mh, mw = spec.ct_h.m, spec.ct_w.m
    p = th * tw
    c_in, c_out = spec.w_shape[2], spec.w_shape[3]
    r = batch * g.n_h * g.n_w
    r_pad = -(-r // br) * br
    c_pad = -(-c_in // bc) * bc
    m_pad = -(-c_out // bm) * bm
    n_mb, n_cb = m_pad // bm, c_pad // bc
    read_x = batch * (g.n_h * mh + th - mh) * (g.n_w * mw + tw - mw) \
        * c_in * elem_bytes
    tiles = r_pad * p * c_pad * elem_bytes
    write_tiles = tiles
    read_tiles = tiles * n_mb                 # re-read per M block
    read_u = (r_pad // br) * n_mb * n_cb * p * bc * bm * filter_elem_bytes
    write_kernel_out = r_pad * mh * mw * m_pad * elem_bytes
    out_nhwc = batch * g.out_h * g.out_w * c_out * elem_bytes
    untile = write_kernel_out + out_nhwc      # transpose/reshape pass
    epilogue = 4 * out_nhwc                   # bias add + relu, each r+w
    return (read_x + write_tiles + read_tiles + read_u + write_kernel_out
            + untile + epilogue)


def separable_fused_hbm_bytes(spec, batch: int = 1, elem_bytes: int = 4,
                              filter_elem_bytes: int | None = None) -> int:
    """Analytic HBM bytes per call of the FUSED separable-block kernel
    (kernels.depthwise.separable_streamed, spec a plan.SeparableSpec): halo
    strip reads (the input block index carries the channel slice and recurs
    per pointwise M block), depthwise-tap and pointwise-filter block reads,
    and the NHWC output write. The depthwise -> pointwise intermediate
    moves ZERO bytes -- it lives in the kernel's VMEM z-cache. Element
    sizes as in streamed_hbm_bytes."""
    if filter_elem_bytes is None:
        filter_elem_bytes = elem_bytes
    s = spec.stream
    th, tw = spec.ct_h.t, spec.ct_w.t
    mh, mw = spec.ct_h.m, spec.ct_w.m
    p = th * tw
    hs = s.bh * mh + th - mh
    ws = s.bw * mw + tw - mw
    n_strips = batch * s.n_hb * s.n_wb
    n_mb = s.m_pad // s.block_m
    read_x = n_strips * hs * ws * s.c_pad * n_mb * elem_bytes
    read_u_dw = n_strips * p * s.c_pad * n_mb * filter_elem_bytes
    read_u_pw = n_strips * s.c_pad * s.m_pad * filter_elem_bytes
    write_y = batch * (s.n_hb * s.bh * mh) * (s.n_wb * s.bw * mw) \
        * s.m_pad * elem_bytes
    return read_x + read_u_dw + read_u_pw + write_y


def separable_unfused_hbm_bytes(dw_spec, pw_mm: int, pw_k: int, pw_n: int,
                                blocks: tuple[int, int, int],
                                batch: int = 1, elem_bytes: int = 4,
                                filter_elem_bytes: int | None = None) -> int:
    """Analytic HBM bytes per call of the UNFUSED Pallas separable pipeline:
    the streamed depthwise kernel (one C sweep of halo strips + taps +
    intermediate write), then the pointwise GEMM kernel re-reading the
    intermediate once per output-channel block plus its filter blocks and
    output write. `dw_spec` is the pallas_depthwise ConvSpec; (pw_mm, pw_k,
    pw_n) the pointwise GEMM dims; `blocks` its (bm, bk, bn). Element sizes
    as in streamed_hbm_bytes."""
    if filter_elem_bytes is None:
        filter_elem_bytes = elem_bytes
    s = dw_spec.stream
    th, tw = dw_spec.ct_h.t, dw_spec.ct_w.t
    mh, mw = dw_spec.ct_h.m, dw_spec.ct_w.m
    p = th * tw
    hs = s.bh * mh + th - mh
    ws = s.bw * mw + tw - mw
    n_strips = batch * s.n_hb * s.n_wb
    read_x = n_strips * hs * ws * s.c_pad * elem_bytes
    read_u_dw = n_strips * p * s.c_pad * filter_elem_bytes
    write_z = batch * (s.n_hb * s.bh * mh) * (s.n_wb * s.bw * mw) \
        * s.c_pad * elem_bytes
    bm_, bk_, bn_ = blocks
    mm_pad = -(-pw_mm // bm_) * bm_
    k_pad = -(-pw_k // bk_) * bk_
    n_pad = -(-pw_n // bn_) * bn_
    n_nb = n_pad // bn_
    read_z = mm_pad * k_pad * n_nb * elem_bytes  # A re-read per N block
    read_u_pw = (mm_pad // bm_) * k_pad * n_pad * filter_elem_bytes
    write_y = mm_pad * n_pad * elem_bytes
    return read_x + read_u_dw + write_z + read_z + read_u_pw + write_y


def strided_streamed_hbm_bytes(spec, batch: int = 1, elem_bytes: int = 4,
                               filter_elem_bytes: int | None = None) -> int:
    """Analytic HBM bytes per call of the stride-2 streaming Winograd kernel
    (kernels.winograd.winograd_strided_streamed): full-resolution halo strip
    reads (2x extent per axis, re-DMA'd per (M sweep, C block)), phase-major
    filter block reads (4P points), and the stride-2 NHWC output write. The
    four phase tile tensors never exist in HBM -- they are gathered in VMEM
    from the one strip."""
    if filter_elem_bytes is None:
        filter_elem_bytes = elem_bytes
    s = spec.stream
    th, tw = spec.ct_h.t, spec.ct_w.t
    mh, mw = spec.ct_h.m, spec.ct_w.m
    p4 = 4 * th * tw
    hs = 2 * (s.bh * mh + th - mh)
    ws = 2 * (s.bw * mw + tw - mw)
    n_strips = batch * s.n_hb * s.n_wb
    n_mb = s.m_pad // s.block_m
    read_x = n_strips * hs * ws * s.c_pad * n_mb * elem_bytes
    read_u = n_strips * p4 * s.c_pad * s.m_pad * filter_elem_bytes
    write_y = batch * (s.n_hb * s.bh * mh) * (s.n_wb * s.bw * mw) \
        * s.m_pad * elem_bytes
    return read_x + read_u + write_y


def pallas_im2row_hbm_bytes(spec, batch: int = 1, elem_bytes: int = 4,
                            filter_elem_bytes: int | None = None) -> int:
    """Analytic HBM bytes per call of the planned Pallas im2row baseline
    (ops.im2col_conv2d_planned): input read, patch-matrix write (the
    kh*kw/(sh*sw) read-amplified copy of the input at stride (sh, sw)),
    per-N-block patch re-reads by the GEMM kernel, filter block reads, and
    the output write (epilogue fused in-kernel)."""
    if filter_elem_bytes is None:
        filter_elem_bytes = elem_bytes
    g = spec.geometry
    bm_, bk_, bn_ = spec.blocks
    kh, kw, cg, c_out = spec.w_shape
    c_in = cg * spec.groups
    mm = batch * g.oh * g.ow
    mm_pad = -(-mm // bm_) * bm_
    k_pad = -(-(kh * kw * c_in) // bk_) * bk_
    n_pad = -(-c_out // bn_) * bn_
    h_in, w_in = spec.x_shape[1:3]
    read_x = batch * (h_in + sum(g.ph)) * (w_in + sum(g.pw)) * c_in \
        * elem_bytes
    patches = mm_pad * k_pad * elem_bytes
    read_patches = patches * (n_pad // bn_)       # A re-read per N block
    read_u = (mm_pad // bm_) * k_pad * n_pad * filter_elem_bytes
    write_y = mm_pad * n_pad * elem_bytes
    return read_x + patches + read_patches + read_u + write_y


def fft_hbm_bytes(spec, batch: int = 1, elem_bytes: int = 4,
                  filter_elem_bytes: int | None = None) -> int:
    """Analytic HBM bytes per call of the rfft2 executor (core.fft, spec a
    plan.ConvSpec with algorithm='fft'): padded input read, real tile tensor
    write + re-read by rfft2, forward spectrum write + re-read by the
    complex pointwise GEMM (complex64 = 8 B), conjugated filter-spectrum
    read, product spectrum write + re-read by irfft2, real inverse write,
    and the cropped NHWC output write. XLA fuses some of these round trips;
    the model is the fusion-free dataflow upper bound, the analogue of
    materialized_hbm_bytes for the Winograd baseline. Spectra are complex
    (2 * elem_bytes per point); the filter spectrum uses filter_elem_bytes
    per real component (the executor itself is fp32-only today, but the
    model stays parametric for symmetry with the Winograd models)."""
    if filter_elem_bytes is None:
        filter_elem_bytes = elem_bytes
    g, f = spec.geometry, spec.fft
    c_in, c_out = spec.w_shape[2], spec.w_shape[3]
    n_tiles = batch * g.n_h * g.n_w
    half_w = f.fft_w // 2 + 1
    read_x = batch * (g.n_h * f.m_h + f.fft_h - f.m_h) \
        * (g.n_w * f.m_w + f.fft_w - f.m_w) * c_in * elem_bytes
    tiles = n_tiles * f.fft_h * f.fft_w * c_in * elem_bytes
    spec_in = n_tiles * f.fft_h * half_w * c_in * 2 * elem_bytes
    read_u = f.fft_h * half_w * c_in * c_out * 2 * filter_elem_bytes
    spec_out = n_tiles * f.fft_h * half_w * c_out * 2 * elem_bytes
    inverse = n_tiles * f.fft_h * f.fft_w * c_out * elem_bytes
    write_y = batch * g.out_h * g.out_w * c_out * elem_bytes
    return (read_x + 2 * tiles + 2 * spec_in + read_u + 2 * spec_out
            + inverse + write_y)


def fft_flops(spec, batch: int = 1) -> int:
    """Analytic real FLOPs per call of the rfft2 executor: forward rfft2
    per input channel + inverse per output channel (split-radix estimate
    2.5 * N * log2(N) for a real transform of N points) plus the complex
    pointwise channel GEMM (8 real flops per complex MAC). The transform
    term is independent of the filter size -- the reason FFT wins on large
    filters."""
    g, f = spec.geometry, spec.fft
    c_in, c_out = spec.w_shape[2], spec.w_shape[3]
    n_tiles = batch * g.n_h * g.n_w
    nf = f.fft_h * f.fft_w
    transform = 2.5 * nf * math.log2(nf)
    gemm = n_tiles * f.fft_h * (f.fft_w // 2 + 1) * c_in * c_out * 8
    return int(n_tiles * (c_in + c_out) * transform + gemm)


def winograd_domain_hbm_bytes(spec, batch: int = 1, elem_bytes: int = 4,
                              filter_elem_bytes: int | None = None) -> int:
    """Analytic HBM bytes per call of a pure-JAX Winograd-domain executor
    (spec a plan.ConvSpec with algorithm='winograd'/'winograd_f63'),
    parameterized by the plan's tile size t = spec.ct_h.t so one model
    covers F(2,3)/F(4,3)/F(6,3): padded input read, (t, t) tile tensor
    write + re-read by the input transform, transformed-tile write +
    re-read by the pointwise GEMM, Winograd-domain filter read, point
    product write + re-read by the output transform, inverse write, and
    the cropped NHWC output write (fusion-free dataflow upper bound)."""
    if filter_elem_bytes is None:
        filter_elem_bytes = elem_bytes
    g = spec.geometry
    th, tw = spec.ct_h.t, spec.ct_w.t
    mh, mw = spec.ct_h.m, spec.ct_w.m
    c_in, c_out = spec.w_shape[2], spec.w_shape[3]
    n_tiles = batch * g.n_h * g.n_w
    read_x = batch * (g.n_h * mh + th - mh) * (g.n_w * mw + tw - mw) \
        * c_in * elem_bytes
    tiles = n_tiles * th * tw * c_in * elem_bytes
    transformed = n_tiles * th * tw * c_in * elem_bytes
    read_u = th * tw * c_in * c_out * filter_elem_bytes
    product = n_tiles * th * tw * c_out * elem_bytes
    inverse = n_tiles * mh * mw * c_out * elem_bytes
    write_y = batch * g.out_h * g.out_w * c_out * elem_bytes
    return (read_x + 2 * tiles + 2 * transformed + read_u + 2 * product
            + inverse + write_y)


def winograd_domain_flops(spec, batch: int = 1) -> int:
    """Analytic real FLOPs per call of a pure-JAX Winograd-domain executor:
    the two-sided input transform (B^T d B) per input channel, the (t*t)
    pointwise channel GEMMs, and the two-sided output transform (A^T z A)
    per output channel. With t = spec.ct_h.t this exposes the F(6,3) vs
    F(4,3) trade: 2.25x fewer GEMM flops per output, more transform flops
    per tile."""
    g = spec.geometry
    th, tw = spec.ct_h.t, spec.ct_w.t
    mh, mw = spec.ct_h.m, spec.ct_w.m
    c_in, c_out = spec.w_shape[2], spec.w_shape[3]
    n_tiles = batch * g.n_h * g.n_w
    in_tr = 2 * (th * th * tw + th * tw * tw)          # B^T d, then (.) B
    out_tr = 2 * (mh * th * tw + mh * mw * tw)         # A^T z, then (.) A
    gemm = n_tiles * th * tw * c_in * c_out * 2
    return int(n_tiles * (c_in * in_tr + c_out * out_tr) + gemm)


def conv_layer_inventory(network: str) -> list[dict]:
    """Every conv layer of a paper network as {name, kh, kw, c_in, c_out,
    h, w, stride, suitable}, collected by tracing the spec interpreter."""
    import jax.numpy as jnp

    from repro.models import cnn

    specs_fn, res = cnn.NETWORKS[network]
    specs = specs_fn()
    params = cnn.init_cnn(jax.random.key(0), specs, 3, res=res)
    layers: dict = {}
    x = jnp.zeros((1, res, res, 3), jnp.float32)
    jax.eval_shape(lambda x: cnn.cnn_forward(params, x, specs,
                                             algorithm="im2col",
                                             layer_times=layers), x)
    return [dict(name=k, **v) for k, v in layers.items()]
