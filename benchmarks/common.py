"""Shared benchmark utilities: wall-clock timing of jitted callables and the
layer-shape inventories of the paper's five networks."""

from __future__ import annotations

import time
from typing import Callable

import jax
import numpy as np


def time_jitted(fn: Callable, *args, warmup: int = 2, iters: int = 5,
                inner: int = 1) -> float:
    """Median wall-time (seconds) of fn(*args) after jit warmup."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        for _ in range(inner):
            out = fn(*args)
        jax.block_until_ready(out)
        times.append((time.perf_counter() - t0) / inner)
    return float(np.median(times))


def conv_layer_inventory(network: str) -> list[dict]:
    """Every conv layer of a paper network as {name, kh, kw, c_in, c_out,
    h, w, stride, suitable}, collected by tracing the spec interpreter."""
    import jax.numpy as jnp

    from repro.models import cnn

    specs_fn, res = cnn.NETWORKS[network]
    specs = specs_fn()
    params = cnn.init_cnn(jax.random.key(0), specs, 3, res=res)
    layers: dict = {}
    x = jnp.zeros((1, res, res, 3), jnp.float32)
    jax.eval_shape(lambda x: cnn.cnn_forward(params, x, specs,
                                             algorithm="im2col",
                                             layer_times=layers), x)
    return [dict(name=k, **v) for k, v in layers.items()]
