"""Serving-runtime benchmark: latency/throughput under Poisson arrivals,
with and without injected faults.

Drives repro.runtime.serve.Server (bounded admission, bucketed dynamic
batching, EDF deadlines, the supervisor degrade ladder) with a seeded
Poisson open-loop client at several arrival rates, then repeats a run per
deterministic fault class (repro.runtime.inject):

  * clean sweep -- p50/p99 latency, throughput, and the bucket-batch
    histogram at each arrival rate (low/medium/overload), so the artifact
    records >= 3 exercised batch buckets;
  * jit A/B -- the same clean traffic with the jitted dispatch fast path
    on vs off (always-eager supervised), recording latency/throughput and
    the jit_dispatches/jit_fallbacks counters for both arms;
  * executor_raise -- a permanently failing layer executor: the ladder must
    re-place it onto the im2row fallback with zero dropped requests and
    every response matching the im2row oracle;
  * latency_spike -- a straggling layer: StepTimer must flag it and the
    supervisor evict it onto the fallback (run with jit_dispatch=False:
    straggler attribution needs the eager path's per-layer timing);
  * corrupt_artifact -- a bit-flipped on-disk NetworkPlan: the per-array
    sha256 digests must catch it at startup and recompile in place;
  * overload -- a burst past queue_capacity: bounded rejection with a
    retry_after hint, and every rejected request completes on resubmit.

Every fault run asserts ZERO dropped in-flight requests (stats.in_flight
== 0 after drain) and ZERO incorrect responses (parity vs the im2row
oracle); the emitted JSON records both gates. BENCH_PR7.json in the repo
root is the committed run; CI uploads BENCH_PR7_ci_<sha>.json per PR.

  PYTHONPATH=src python -m benchmarks.serving --smoke --out BENCH_PR7.json
  PYTHONPATH=src python -m benchmarks.run --json BENCH_PR7.json \
      --config serving
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import numpy as np

import jax

from benchmarks.common import bench_metadata
from repro.core import compile as C
from repro.models import cnn
from repro.runtime import inject
from repro.runtime.serve import QueueFullError, ServeConfig, Server

TOL = 2e-3


def specs_for(res: int):
    return [cnn.Conv("c1", 3, 3, 16),
            cnn.Conv("c2", 3, 3, 16),
            cnn.Conv("c3", 3, 3, 32, stride=2),
            cnn.Conv("c4", 3, 3, 32, relu=False)]


def make_cfg(**kw) -> ServeConfig:
    base = dict(buckets=(1, 2, 4, 8), queue_capacity=64, verbose=False,
                backoff_base_s=0.002, backoff_cap_s=0.02)
    base.update(kw)
    return ServeConfig(**base)


def oracle_outputs(params, specs, res, inputs):
    import jax.numpy as jnp
    net = C.compile(params, specs, res=res, batch=1, algorithm="im2col")
    return [np.asarray(net.apply(jnp.asarray(x[None])))[0] for x in inputs]


def parity(results, oracle):
    """(max_rel_err, n_incorrect) of answered (idx, y) pairs vs oracle."""
    worst, bad = 0.0, 0
    for idx, y in results:
        ref = oracle[idx]
        err = float(np.max(np.abs(y - ref)) / (np.max(np.abs(ref)) + 1e-9))
        worst = max(worst, err)
        bad += err >= TOL
    return worst, bad


def poisson_run(srv, inputs, *, rate: float, n: int, seed: int,
                resubmit: bool = False, deadline_s: float | None = None):
    """Open-loop Poisson client: n submissions at `rate` req/s (seeded
    exponential inter-arrivals) drawing inputs from the oracle pool.
    On QueueFullError: count the rejection and either drop the arrival
    (clean sweep -- that's what bounded admission means) or honor
    retry_after_s and resubmit until admitted (overload drill)."""
    rng = np.random.default_rng(seed)
    tickets, rejected, resubmits = [], 0, 0
    t0 = time.perf_counter()
    for _ in range(n):
        time.sleep(rng.exponential(1.0 / rate))
        idx = int(rng.integers(len(inputs)))
        while True:
            try:
                tickets.append((idx, srv.submit(inputs[idx],
                                                deadline_s=deadline_s)))
                break
            except QueueFullError as e:
                rejected += 1
                if not resubmit:
                    break
                time.sleep(max(e.retry_after_s, 1e-3))
                resubmits += 1
    results, lat = [], []
    for idx, t in tickets:
        try:
            results.append((idx, t.result(timeout=300)))
            lat.append(t.latency_s)
        except (TimeoutError, RuntimeError):
            pass                      # deadline-expired / cancelled tickets
    span = time.perf_counter() - t0
    row = {"rate_rps": rate, "offered": n, "admitted": len(tickets),
           "rejected": rejected, "resubmits": resubmits,
           "completed": len(results), "span_s": round(span, 3),
           "throughput_rps": round(len(results) / span, 1)}
    if lat:
        row.update(
            p50_ms=round(float(np.percentile(lat, 50)) * 1e3, 3),
            p99_ms=round(float(np.percentile(lat, 99)) * 1e3, 3),
            mean_ms=round(float(np.mean(lat)) * 1e3, 3))
    return row, results


def run_clean_sweep(params, specs, res, inputs, oracle, rates, n, seed):
    rows = []
    for rate in rates:
        srv = Server(params, specs, res=res, algorithm="auto",
                     config=make_cfg())
        with srv:
            row, results = poisson_run(srv, inputs, rate=rate, n=n,
                                       seed=seed)
        err, bad = parity(results, oracle)
        s = srv.stats
        row.update(bucket_batches=s.snapshot()["bucket_batches"],
                   batches=s.batches, dropped=s.in_flight,
                   parity_max_rel_err=round(err, 6), incorrect=bad)
        rows.append(row)
        print(f"  rate {rate:>6.0f}/s: p50 {row.get('p50_ms', 0):7.2f} ms  "
              f"p99 {row.get('p99_ms', 0):7.2f} ms  "
              f"tput {row['throughput_rps']:7.1f}/s  "
              f"buckets {row['bucket_batches']}", flush=True)
    return rows


def fault_row(name, srv, row, results, oracle, extra=()):
    err, bad = parity(results, oracle)
    s = srv.stats.snapshot()
    out = {"fault": name, **row, "parity_max_rel_err": round(err, 6),
           "incorrect": bad, "dropped": s["in_flight"],
           **{k: s[k] for k in ("retries", "replacements", "evictions",
                                "stragglers", "recompiles",
                                "executor_failures", "corrupt_artifacts",
                                "corrupt_arrays", "failed", "timed_out")},
           **dict(extra)}
    print(f"  {name:>16}: completed {row['completed']}/{row['offered']}  "
          f"dropped {out['dropped']}  incorrect {bad}  "
          f"ladder(retries={out['retries']}, repl={out['replacements']}, "
          f"evict={out['evictions']}, recompile={out['recompiles']})",
          flush=True)
    return out


def run_jit_ab(params, specs, res, inputs, oracle, rate, n, seed):
    """A/B the jitted dispatch fast path (whole-network jit until the
    bucket's first fault) against the always-eager supervised path on
    identical clean Poisson traffic."""
    rows = []
    for jit_on in (True, False):
        srv = Server(params, specs, res=res, algorithm="auto",
                     config=make_cfg(jit_dispatch=jit_on))
        with srv:
            row, results = poisson_run(srv, inputs, rate=rate, n=n,
                                       seed=seed)
        err, bad = parity(results, oracle)
        s = srv.stats
        row.update(jit_dispatch=jit_on, jit_dispatches=s.jit_dispatches,
                   jit_fallbacks=s.jit_fallbacks, dropped=s.in_flight,
                   parity_max_rel_err=round(err, 6), incorrect=bad)
        rows.append(row)
        print(f"  jit={str(jit_on):>5}: p50 {row.get('p50_ms', 0):7.2f} ms  "
              f"p99 {row.get('p99_ms', 0):7.2f} ms  "
              f"tput {row['throughput_rps']:7.1f}/s  "
              f"jit_dispatches {s.jit_dispatches}", flush=True)
    return rows


def run_faults(params, specs, res, inputs, oracle, rate, n, seed):
    rows = []

    # -- executor raise: permanent kernel failure mid-traffic -------------
    srv = Server(params, specs, res=res, algorithm="auto", config=make_cfg())
    with srv:
        inject.install_on_server(srv, inject.ExecutorRaise("c2"))
        row, results = poisson_run(srv, inputs, rate=rate, n=n, seed=seed)
    rows.append(fault_row("executor_raise", srv, row, results, oracle))

    # -- latency spike: straggling layer -> eviction ----------------------
    # straggler attribution needs the eager path's per-layer timing hooks
    # from the first batch, so the jitted fast path is off for this drill.
    srv = Server(params, specs, res=res, algorithm="auto",
                 config=make_cfg(jit_dispatch=False,
                                 straggler_window=16,
                                 straggler_min_baseline=5,
                                 straggler_evict_after=2))
    with srv:
        warm, _ = poisson_run(srv, inputs, rate=rate, n=n, seed=seed)
        inject.install_on_server(
            srv, inject.LatencySpike("c3", delay_s=0.25))
        row, results = poisson_run(srv, inputs, rate=rate, n=n,
                                   seed=seed + 1)
    row["offered"] += warm["offered"]
    row["completed"] += warm["completed"]
    rows.append(fault_row("latency_spike", srv, row, results, oracle))

    # -- corrupt artifact: bit-flip caught by sha256 at startup -----------
    with tempfile.TemporaryDirectory() as art:
        cfg = make_cfg()
        Server(params, specs, res=res, algorithm="auto", config=cfg,
               artifact_dir=art)                   # compile + save artifacts
        flipped = inject.flip_bit(
            os.path.join(art, f"plan_b{max(cfg.buckets)}.npz"))
        srv = Server(params, specs, res=res, algorithm="auto", config=cfg,
                     artifact_dir=art)
        with srv:
            row, results = poisson_run(srv, inputs, rate=rate, n=n,
                                       seed=seed)
        rows.append(fault_row(
            "corrupt_artifact", srv, row, results, oracle,
            extra=[("flipped_array", flipped),
                   ("warm_starts", srv.stats.artifact_warm_starts),
                   ("cold_starts", srv.stats.artifact_cold_starts)]))

    # -- overload: burst past capacity -> bounded rejection + resubmit ----
    srv = Server(params, specs, res=res, algorithm="auto",
                 config=make_cfg(queue_capacity=8))
    with srv:
        row, results = poisson_run(srv, inputs, rate=rate * 20, n=n,
                                   seed=seed, resubmit=True)
    rows.append(fault_row("overload", srv, row, results, oracle,
                          extra=[("queue_capacity", 8)]))
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_PR7.json")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: fewer requests per rate")
    ap.add_argument("--res", type=int, default=32)
    ap.add_argument("--requests", type=int, default=None,
                    help="submissions per clean-sweep rate "
                         "(default 60 smoke / 200 full)")
    ap.add_argument("--rates", type=float, nargs="*", default=None,
                    help="Poisson arrival rates, req/s (default: low / "
                         "medium / overload)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    n = args.requests or (60 if args.smoke else 200)
    rates = args.rates or [50.0, 200.0, 1000.0]
    res = args.res
    specs = specs_for(res)
    params = cnn.init_cnn(jax.random.key(args.seed), specs, 3, res=res)
    rng = np.random.default_rng(args.seed)
    inputs = [rng.standard_normal((res, res, 3)).astype(np.float32)
              for _ in range(8)]
    print(f"serving benchmark: res={res}, {len(specs)} layers, "
          f"{n} requests/rate, rates={rates}", flush=True)
    oracle = oracle_outputs(params, specs, res, inputs)

    t0 = time.time()
    print("clean Poisson sweep:", flush=True)
    clean = run_clean_sweep(params, specs, res, inputs, oracle, rates, n,
                            args.seed)
    buckets_hit = sorted({int(b) for row in clean
                          for b in row["bucket_batches"]})
    print("jitted vs eager dispatch A/B:", flush=True)
    jit_ab = run_jit_ab(params, specs, res, inputs, oracle,
                        rate=rates[len(rates) // 2], n=n, seed=args.seed)
    print("fault drills:", flush=True)
    faults = run_faults(params, specs, res, inputs, oracle,
                        rate=rates[len(rates) // 2], n=n, seed=args.seed)

    zero_dropped = (all(r["dropped"] == 0 for r in clean + jit_ab)
                    and all(r["dropped"] == 0 for r in faults))
    zero_incorrect = (all(r["incorrect"] == 0 for r in clean + jit_ab)
                      and all(r["incorrect"] == 0 for r in faults))
    survived = {r["fault"]: bool(
        r["replacements"] if r["fault"] == "executor_raise"
        else r["evictions"] if r["fault"] == "latency_spike"
        else r["corrupt_artifacts"] if r["fault"] == "corrupt_artifact"
        else r["rejected"] and r["completed"] == r["offered"])
        for r in faults}

    out = {"meta": bench_metadata(),
           "benchmark": "serving",
           "config": {"res": res, "layers": [s.name for s in specs],
                      "requests_per_rate": n, "rates_rps": rates,
                      "buckets": list(make_cfg().buckets),
                      "seed": args.seed, "smoke": args.smoke,
                      "parity_tol": TOL},
           "clean": clean,
           "jit_ab": jit_ab,
           "buckets_exercised": buckets_hit,
           "faults": faults,
           "fault_survived": survived,
           "zero_dropped": zero_dropped,
           "zero_incorrect": zero_incorrect}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"\nbuckets exercised: {buckets_hit}; "
          f"faults survived: {survived}; "
          f"zero_dropped={zero_dropped} zero_incorrect={zero_incorrect}; "
          f"wrote {args.out} in {time.time() - t0:.0f}s", flush=True)
    if not (zero_dropped and zero_incorrect and all(survived.values())
            and len(buckets_hit) >= 3):
        raise SystemExit("serving fault gate FAILED (see JSON)")


if __name__ == "__main__":
    main()
