"""Benchmark driver: one harness per paper table/figure + the roofline table.

  PYTHONPATH=src python -m benchmarks.run               # quick CPU pass
  PYTHONPATH=src python -m benchmarks.run --full        # full layer sweeps
  PYTHONPATH=src python -m benchmarks.run --json BENCH_ci.json
      # emit the perf-trajectory artifact: per-layer steady-state ms +
      # HBM bytes moved for the streamed vs pre-streaming Pallas Winograd
      # paths on the VGG-style config (CI uploads this; BENCH_PR2.json in
      # the repo root is the committed run for that config)
  PYTHONPATH=src python -m benchmarks.run --json BENCH_PR4.json \
      --config mobilenet
      # same artifact on the MobileNet ladders: fused separable streamed
      # kernel vs the unfused two-kernel pipeline, the stride-2 Winograd
      # (transform-domain phase decomposition) vs im2row A/B on the
      # reduction-block ladder, and the fused-vs-composed MobileNet-v2
      # inverted-residual A/B (BENCH_PR3.json / BENCH_PR4.json in the repo
      # root are the committed runs; CI runs the quick variant per PR)
  PYTHONPATH=src python -m benchmarks.run --json BENCH_PR5.json \
      --config compile
      # whole-network startup A/B through the graph compiler: cold
      # compile() vs warm NetworkPlan.load() artifact, artifact size, a
      # fresh-process bitwise parity gate, and planned-vs-im2row
      # steady-state (BENCH_PR5.json is the committed run)
  PYTHONPATH=src python -m benchmarks.run --json BENCH_PR6.json \
      --config crossover
      # the N-way measured auto_tuned race (im2row / F(2,3) / F(4,3) /
      # F(6,3) / FFT) over the filter-size x resolution x channel
      # crossover grid plus the VGG and MobileNet-v2 ladders, with the
      # per-contender plan-time evidence and the end-to-end time of the
      # chosen policy per layer (BENCH_PR6.json is the committed run)
  PYTHONPATH=src python -m benchmarks.run --json BENCH_PR7.json \
      --config serving
      # the serving runtime under Poisson arrivals: p50/p99 latency +
      # throughput per arrival rate over the bucketed batch plans, then
      # one drill per injected fault class (executor raise, latency
      # spike, corrupt artifact, queue overload) gated on zero dropped
      # requests and zero incorrect responses vs the im2row oracle
      # (BENCH_PR7.json is the committed run)
  PYTHONPATH=src python -m benchmarks.run --json BENCH_PR9.json \
      --config scaling
      # the 1 -> 8 device scaling curve for sharded NetworkPlan execution
      # (data-parallel batch sharding + spatial halo partitioning), each
      # device count in a fresh forced-host-device subprocess, gated on
      # parity vs the unsharded oracle, strictly increasing normalized
      # throughput, >= 3x aggregate at 8 devices, and the version-5
      # artifact restoring the recorded partition on warm start
      # (BENCH_PR9.json is the committed run)
  PYTHONPATH=src python -m benchmarks.run --json BENCH_PR8.json \
      --config precision
      # the mixed-precision A/B: per-layer fp32/bf16/int8 plans over the
      # deep VGG + MobileNet ladders (measured times, analytic HBM bytes
      # with reduced filter payloads, per-layer accuracy), the unpinned
      # auto_tuned race evidence, and the MobileNet-v2 whole-network
      # policy A/B gated on int8 logits top-1 agreement vs fp32
      # (BENCH_PR8.json is the committed run)
  PYTHONPATH=src python -m benchmarks.run --json BENCH_PR10.json \
      --config observe
      # the observability A/B: MobileNet-v2 served with the profiler
      # disabled vs enabled (interleaved rounds, machine-relative
      # overhead %), the per-request span decomposition audited against
      # measured latency, and the chrome://tracing + metrics-snapshot
      # exports written next to the JSON (BENCH_PR10.json is the
      # committed run; benchmarks/regress.py gates CI against it)

Every emitted BENCH_*.json is stamped with jax version, backend/device
kind, git SHA and a UTC timestamp (benchmarks.common.bench_metadata), so
artifacts from different runs/machines are comparable.

Quick mode trims iteration counts and caps per-network layer counts so the
whole suite finishes in minutes on one CPU core; --full runs every unique
layer at paper resolution.
"""

from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--skip", nargs="*", default=[],
                    choices=["per_layer", "whole_network", "fast_fraction",
                             "amortization", "roofline"])
    ap.add_argument("--plan-cache", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="A/B switch for the amortization benchmark: each row "
                         "records a cold plan build plus a rebuild that hits "
                         "the spec cache (--plan-cache, default) or starts "
                         "cold again (--no-plan-cache), next to per-call and "
                         "planned steady-state times")
    ap.add_argument("--json", default=None, metavar="BENCH_<tag>.json",
                    help="run ONLY the benchmark of the chosen --config "
                         "(quick variant unless --full) and write its "
                         "artifact, stamped with jax/backend/git-SHA "
                         "metadata, to this path")
    ap.add_argument("--config", default="vgg_style",
                    choices=["vgg_style", "mobilenet", "compile",
                             "crossover", "serving", "precision",
                             "scaling", "observe"],
                    help="which --json benchmark to run: vgg_style "
                         "(streamed vs materialized dense Winograd), "
                         "mobilenet (fused vs unfused separable blocks), "
                         "compile (whole-network cold-compile vs "
                         "warm-artifact startup + fresh-process parity "
                         "via the graph compiler), crossover (the "
                         "N-way measured auto_tuned race over the "
                         "filter x resolution x channel grid + VGG/MBv2 "
                         "ladders -- BENCH_PR6.json), or serving (the "
                         "fault-tolerant batched serving runtime under "
                         "Poisson arrivals + per-fault-class drills -- "
                         "BENCH_PR7.json), or precision (the per-layer "
                         "and whole-network fp32/bf16/int8 A/B with the "
                         "int8 top-1 accuracy gate -- BENCH_PR8.json), "
                         "or observe (the observability overhead A/B + "
                         "span decomposition audit -- BENCH_PR10.json)")
    args = ap.parse_args(argv)

    from benchmarks import (amortization, fast_fraction, per_layer, roofline,
                            serving, startup, whole_network)

    t0 = time.time()

    if args.json:
        if args.config == "serving":
            serving.main(["--out", args.json]
                         + ([] if args.full else ["--smoke"]))
        elif args.config == "precision":
            from benchmarks import precision
            precision.main(["--out", args.json]
                           + ([] if args.full else ["--quick"]))
        elif args.config == "scaling":
            from benchmarks import scaling
            scaling.main(["--out", args.json]
                         + ([] if args.full else ["--quick"]))
        elif args.config == "observe":
            from benchmarks import observe
            observe.main(["--out", args.json]
                         + ([] if args.full else ["--quick"]))
        elif args.config == "compile":
            res = "224" if args.full else "96"
            iters = "3" if args.full else "2"
            startup.main(["--res", res, "--iters", iters, "--warmup", "1",
                          "--out", args.json])
        else:
            cfg = args.config if args.full else f"{args.config}_quick"
            iters = "3" if args.full else "2"
            per_layer.main(["--config", cfg, "--iters", iters,
                            "--warmup", "1", "--out", args.json])
        print(f"\nwrote {args.json} in {time.time() - t0:.0f}s")
        return

    quick_nets = ["vgg16", "googlenet", "inception_v3", "squeezenet"]

    if "per_layer" not in args.skip:
        print("\n#### benchmarks.per_layer (paper Table 2) ####", flush=True)
        pl_args = ["--iters", "3"] if args.full else \
            ["--iters", "2", "--warmup", "1", "--max-layers-per-net", "6",
             "--networks", *quick_nets]
        per_layer.main(pl_args + ["--out", "results/bench_per_layer.json"])

    if "whole_network" not in args.skip:
        print("\n#### benchmarks.whole_network (paper Table 1) ####",
              flush=True)
        wn_args = [] if args.full else \
            ["--iters", "2", "--networks", *quick_nets]
        whole_network.main(wn_args + ["--out",
                                      "results/bench_whole_network.json"])

    if "fast_fraction" not in args.skip:
        print("\n#### benchmarks.fast_fraction (paper Fig 3) ####", flush=True)
        ff_args = [] if args.full else \
            ["--iters", "1", "--warmup", "1", "--networks", "squeezenet",
             "googlenet"]
        fast_fraction.main(ff_args + ["--out",
                                      "results/bench_fast_fraction.json"])

    if "amortization" not in args.skip:
        print("\n#### benchmarks.amortization (paper section 4) ####",
              flush=True)
        am_args = [] if args.full else ["--iters", "3",
                                        "--m-sweep", "16", "64", "256"]
        am_args += ["--plan-cache" if args.plan_cache else "--no-plan-cache"]
        amortization.main(am_args + ["--out",
                                     "results/bench_amortization.json"])

    if "roofline" not in args.skip:
        print("\n#### benchmarks.roofline (dry-run artifacts) ####",
              flush=True)
        roofline.main(["--out", "results/bench_roofline.json"])
        print("\n#### roofline, optimized phase (EXPERIMENTS.md "
              "section Perf hillclimb cells) ####", flush=True)
        roofline.main(["--phase", "optimized",
                       "--out", "results/bench_roofline_optimized.json"])

    print(f"\nbenchmarks done in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
