"""Paper Table 1 + Fig 3: whole-network latency under the two benchmark
configurations -- (a) our scheme on suitable layers + im2row elsewhere
(algorithm="auto"), (b) im2row everywhere -- and the fast-layer runtime
fraction, for the five paper networks at batch size 1.

Also reports the plan/execute split (the paper's section-4 deployment
setting): one-time plan-build cost (all filter transforms + geometry) vs
steady-state planned forward time, separately -- mirroring the paper's
amortization analysis at whole-network scale."""

from __future__ import annotations

import argparse
import functools
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compile import compile as compile_network
from repro.models import cnn

from benchmarks.common import time_jitted

NETWORKS = ["vgg16", "vgg19", "googlenet", "inception_v3", "squeezenet",
            "mobilenet_v1"]


def bench_network(net: str, iters: int, warmup: int, res: int | None = None
                  ) -> dict:
    specs_fn, default_res = cnn.NETWORKS[net]
    res = res or default_res
    specs = specs_fn()
    params = cnn.init_cnn(jax.random.key(0), specs, 3, res=res)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        (1, res, res, 3)), jnp.float32)

    fwd = {}
    for algo in ("auto", "auto_tuned", "im2col"):
        fn = jax.jit(functools.partial(cnn.cnn_forward, params, specs=specs,
                                       algorithm=algo))
        fwd[algo] = time_jitted(fn, x, warmup=warmup, iters=iters)

    # plan/execute split via the graph compiler: lowering, fusion rewrites,
    # placement, filter transforms once -- then steady-state NetworkPlan
    # execution.
    t0 = time.perf_counter()
    net_plan = compile_network(params, specs, res=res, algorithm="auto")
    jax.block_until_ready(net_plan.weight_arrays())
    plan_build = time.perf_counter() - t0
    fn_planned = jax.jit(net_plan.apply)
    fwd["planned"] = time_jitted(fn_planned, x, warmup=warmup, iters=iters)

    return {"network": net, "res": res,
            "t_ours_s": fwd["auto"], "t_tuned_s": fwd["auto_tuned"],
            "t_im2row_s": fwd["im2col"],
            "t_planned_s": fwd["planned"], "plan_build_s": plan_build,
            "speedup_pct": 100.0 * (1 - fwd["auto"] / fwd["im2col"]),
            "speedup_tuned_pct":
                100.0 * (1 - fwd["auto_tuned"] / fwd["im2col"]),
            "speedup_planned_pct":
                100.0 * (1 - fwd["planned"] / fwd["im2col"])}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--networks", nargs="*", default=NETWORKS)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--res", type=int, default=None,
                    help="override input resolution (CPU-quick runs)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    rows = []
    print("== Table 1 reproduction: whole-network latency (batch 1) ==")
    print(f"{'Network':14s} {'im2row(ms)':>11s} {'ours(ms)':>10s} "
          f"{'speedup':>8s} {'tuned(ms)':>10s} {'tuned-spd':>9s} "
          f"{'planned(ms)':>12s} {'build(ms)':>10s} {'plan-spd':>9s}")
    for net in args.networks:
        r = bench_network(net, args.iters, args.warmup, args.res)
        rows.append(r)
        print(f"{r['network']:14s} {r['t_im2row_s']*1e3:11.1f} "
              f"{r['t_ours_s']*1e3:10.1f} {r['speedup_pct']:7.1f}% "
              f"{r['t_tuned_s']*1e3:10.1f} {r['speedup_tuned_pct']:8.1f}% "
              f"{r['t_planned_s']*1e3:12.1f} {r['plan_build_s']*1e3:10.1f} "
              f"{r['speedup_planned_pct']:8.1f}%",
              flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
