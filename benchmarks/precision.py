"""Mixed-precision A/B: fp32 vs bf16 vs int8 transform-domain execution.

Per-layer pass over the deep, filter-dominated slices of the VGG-16 ladder
and the MobileNet-v1 separable ladder (depthwise + pointwise halves): each
layer is planned three times with compute_dtype pinned to
float32 / bfloat16 / int8, timed end to end (jitted, batch 1), scored on
accuracy against its own fp32 plan (max relative error + top-1 agreement
over the channel axis), and priced by the analytic HBM-bytes model of
whichever executor the plan resolved to, with the filter payload at the
plan's storage dtype (benchmarks.common dtype_bytes). On a machine without
reduced-precision GEMM instructions the *measured* times are reported
honestly (wins_by_time); the paper-relevant figure of merit on a
bandwidth-bound mobile CPU is the bytes model (wins_by_hbm_model) -- see
EXPERIMENTS.md section PR 8 for the crossover analysis.

Each layer also runs the unpinned measured auto_tuned race once and records
the full per-contender evidence (t_* timings + err_* accuracy probes vs the
fp32 oracle), demonstrating that the policy selects a reduced dtype only
where it measured faster AND passed the plan-time accuracy budget.

Whole-network pass: MobileNet-v2 (width 0.5) compiled through the graph
API at each policy dtype -- steady-state apply time, serialized artifact
size, count of layers actually lowered to the reduced dtype, and logits
top-1 agreement vs the fp32 network over a pool of random inputs. The int8
top-1 agreement is the CI accuracy gate: the run exits non-zero when it
falls below --top1-threshold.

  PYTHONPATH=src python -m benchmarks.precision --out BENCH_PR8.json
  PYTHONPATH=src python -m benchmarks.run --json BENCH_PR8.json \
      --config precision          # quick variant unless --full
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import (bench_metadata, dtype_bytes, fft_hbm_bytes,
                               materialized_hbm_bytes,
                               pallas_im2row_hbm_bytes, streamed_hbm_bytes,
                               strided_streamed_hbm_bytes, time_jitted,
                               winograd_domain_hbm_bytes)
from benchmarks.per_layer import MOBILENET_LAYERS, VGG_STYLE_LAYERS, scaled
from repro.core import compile as C
from repro.core import plan as planlib
from repro.models import cnn

DTYPES = ("float32", "bfloat16", "int8")


def precision_layers(scale: int = 1) -> list[dict]:
    """The mixed-precision ladder: the deep VGG-16 3x3 layers (where the
    transformed-filter tensor, O(P * C * M), dominates HBM traffic and a
    bf16/int8 payload halves/quarters the bound) plus the deep MobileNet-v1
    separable blocks split into their depthwise and pointwise halves."""
    vgg = [dict(l, stride=1) for l in VGG_STYLE_LAYERS
           if l["c_in"] >= 128]
    mb = []
    for l in MOBILENET_LAYERS:
        if l["c_in"] < 256:
            continue
        mb.append(dict(name=f"{l['name']}_dw", kh=l["k"], kw=l["k"],
                       h=l["h"], w=l["w"], c_in=l["c_in"], c_out=l["c_in"],
                       stride=1, groups=l["c_in"]))
        mb.append(dict(name=f"{l['name']}_pw", kh=1, kw=1, h=l["h"],
                       w=l["w"], c_in=l["c_in"], c_out=l["c_out"],
                       stride=1))
    return scaled(vgg + mb, scale)


def plan_hbm_bytes(p, batch: int = 1) -> int:
    """Analytic HBM bytes of a ConvPlan under the bytes model of whichever
    executor it resolved to, with the transform-domain filter payload at
    the plan's storage dtype (fp32/bf16/int8)."""
    spec = p.spec
    fb = dtype_bytes(spec.compute_dtype)
    ex = spec.algorithm
    if ex == "fft":
        return fft_hbm_bytes(spec, batch, filter_elem_bytes=fb)
    if ex == "pallas_im2col":
        return pallas_im2row_hbm_bytes(spec, batch, filter_elem_bytes=fb)
    if ex == "pallas_winograd":
        return streamed_hbm_bytes(spec, batch, filter_elem_bytes=fb)
    if ex == "pallas_winograd_strided":
        return strided_streamed_hbm_bytes(spec, batch, filter_elem_bytes=fb)
    if ex == "pallas_winograd_materialized":
        return materialized_hbm_bytes(spec, batch, filter_elem_bytes=fb)
    if ex.startswith("winograd"):
        return winograd_domain_hbm_bytes(spec, batch, filter_elem_bytes=fb)
    # XLA im2col: padded input read, filter read at storage dtype, output
    # write (the implicit patch matrix stays in registers/cache under XLA).
    kh, kw, cg, c_out = spec.w_shape
    g = spec.geometry
    _, h, w, c_in = spec.x_shape
    read_x = batch * (h + sum(g.ph)) * (w + sum(g.pw)) * c_in * 4
    read_u = kh * kw * cg * c_out * fb
    write_y = batch * g.oh * g.ow * c_out * 4
    return read_x + read_u + write_y


def accuracy(y: np.ndarray, ref: np.ndarray) -> tuple[float, float]:
    """(max relative error, channel-axis top-1 agreement) vs the fp32
    reference -- the per-layer analogue of the logits top-1 gate."""
    rel = float(np.max(np.abs(y - ref)) / (np.max(np.abs(ref)) + 1e-9))
    top1 = float(np.mean(np.argmax(y, axis=-1) == np.argmax(ref, axis=-1)))
    return rel, top1


def bench_layer(layer: dict, iters: int, warmup: int) -> dict:
    rng = np.random.default_rng(0)
    groups = layer.get("groups", 1)
    x = jnp.asarray(rng.standard_normal(
        (1, layer["h"], layer["w"], layer["c_in"])), jnp.float32)
    wt = jnp.asarray(rng.standard_normal(
        (layer["kh"], layer["kw"], layer["c_in"] // groups,
         layer["c_out"])) / (layer["kh"] * layer["kw"]), jnp.float32)
    row = {"layer": layer["name"], "groups": groups,
           "shape": f"{layer['h']}x{layer['w']}x{layer['c_in']}"
                    f"->{layer['c_out']}"
                    f"{f'/g{groups}' if groups > 1 else ''}",
           "filter": f"{layer['kh']}x{layer['kw']}"}
    ref = None
    for cd in DTYPES:
        p = planlib.plan_conv2d(x.shape, wt, stride=layer["stride"],
                                groups=groups, algorithm="auto",
                                compute_dtype=cd)
        fn = jax.jit(p.apply)
        t = time_jitted(fn, x, warmup=warmup, iters=iters)
        y = np.asarray(fn(x), np.float32)
        if ref is None:
            ref = y
        rel, top1 = accuracy(y, ref)
        row[cd] = {"executor": p.spec.algorithm,
                   "tile": (list(p.spec.output_tile)
                            if p.spec.output_tile else None),
                   "t_s": t, "hbm_model_bytes": plan_hbm_bytes(p),
                   "rel_err": round(rel, 6), "top1_agreement": top1}
    # The dtype-opted measured race (compute_dtype="auto"): fp32
    # contenders plus the gated bf16/int8 variants, with accuracy
    # evidence recorded next to the timings.
    pt = planlib.plan_conv2d(x.shape, wt, stride=layer["stride"],
                             groups=groups, algorithm="auto_tuned",
                             compute_dtype="auto")
    report = pt.spec.autotune_report or {}
    row["auto_tuned"] = {
        "winner": pt.spec.algorithm,
        "winner_label": report.get("winner_label"),
        "compute_dtype": pt.spec.compute_dtype,
        "decision": pt.describe()["decision"],
        "evidence": {k: v for k, v in report.items()
                     if k.startswith("t_")},
        "accuracy": {k: v for k, v in report.items()
                     if k.startswith("err_")}}
    return row


def run_layers(scale: int, iters: int, warmup: int) -> tuple[list, dict]:
    rows = []
    print(f"== per-layer fp32/bf16/int8 A/B (scale 1/{scale}) ==",
          flush=True)
    for l in precision_layers(scale):
        r = bench_layer(l, iters, warmup)
        rows.append(r)
        f32, bf, i8 = r["float32"], r["bfloat16"], r["int8"]
        print(f"{r['layer']:12s} {r['shape']:22s} "
              f"fp32 {f32['t_s']*1e3:7.2f}ms/{f32['hbm_model_bytes']>>10:6d}KiB  "
              f"bf16 {bf['t_s']*1e3:7.2f}ms/{bf['hbm_model_bytes']>>10:6d}KiB  "
              f"int8 {i8['t_s']*1e3:7.2f}ms/{i8['hbm_model_bytes']>>10:6d}KiB "
              f"err={i8['rel_err']:.3f} "
              f"tuned={r['auto_tuned']['winner_label']}",
              flush=True)

    def wins(metric):
        return {cd: sum(r[cd][metric] < r["float32"][metric] for r in rows)
                for cd in DTYPES[1:]} | {
            "any_reduced": sum(min(r[cd][metric] for cd in DTYPES[1:])
                               < r["float32"][metric] for r in rows)}

    # "reduced only where it wins": every auto_tuned race that crowned a
    # bf16/int8 variant must show that variant measuring faster than every
    # fp32 contender AND passing the plan-time accuracy budget.
    tuned_ok = True
    n_tuned_reduced = 0
    for r in rows:
        at = r["auto_tuned"]
        if at["compute_dtype"] == "float32":
            continue
        n_tuned_reduced += 1
        ev, lbl = at["evidence"], at["winner_label"]
        t_win = ev.get(f"t_{lbl}_s")
        fp32_ts = [v for k, v in ev.items()
                   if not k[2:-2].endswith(("_bf16", "_int8"))]
        err = at["accuracy"].get(f"err_{lbl}")
        budget = planlib.AUTOTUNE_ACCURACY_BUDGET[at["compute_dtype"]]
        tuned_ok &= (t_win is not None and t_win <= min(fp32_ts)
                     and err is not None and err <= budget)
    summary = {"n_layers": len(rows),
               "wins_by_hbm_model": wins("hbm_model_bytes"),
               "wins_by_time": wins("t_s"),
               "max_rel_err": {cd: max(r[cd]["rel_err"] for r in rows)
                               for cd in DTYPES[1:]},
               "min_top1_agreement": {cd: min(r[cd]["top1_agreement"]
                                              for r in rows)
                                      for cd in DTYPES[1:]},
               "auto_tuned_reduced_selected": n_tuned_reduced,
               "auto_tuned_reduced_only_where_wins": bool(tuned_ok)}
    print(f"\nwins_by_hbm_model: {summary['wins_by_hbm_model']}  "
          f"wins_by_time: {summary['wins_by_time']}\n"
          f"auto_tuned picked reduced on {n_tuned_reduced}/{len(rows)} "
          f"layers, only-where-wins={tuned_ok}", flush=True)
    return rows, summary


def run_network(res: int, n_inputs: int, iters: int, warmup: int,
                seed: int) -> dict:
    specs = cnn.mobilenet_v2(0.5)
    params = cnn.init_cnn(jax.random.PRNGKey(seed), specs, 3, res=res)
    rng = np.random.default_rng(seed)
    xs = [jnp.asarray(rng.standard_normal((1, res, res, 3)), jnp.float32)
          for _ in range(n_inputs)]
    print(f"\n== MobileNet-v2 (width 0.5, res {res}) network policy A/B ==",
          flush=True)
    out, ref = {}, None
    for cd in DTYPES:
        t0 = time.time()
        net = C.compile(params, specs, res=res, batch=1, algorithm="auto",
                        compute_dtype=cd)
        build_s = time.time() - t0
        t = time_jitted(net.apply, xs[0], warmup=warmup, iters=iters)
        ys = np.stack([np.asarray(net.apply(x), np.float32)[0]
                       for x in xs])
        if ref is None:
            ref = ys
        rel = float(np.max(np.abs(ys - ref))
                    / (np.max(np.abs(ref)) + 1e-9))
        top1 = float(np.mean(np.argmax(ys, -1) == np.argmax(ref, -1)))
        dtypes = [p.describe().get("compute_dtype", "float32")
                  for p in net.plans.values()]
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "plan.npz")
            net.save(path)
            artifact_bytes = os.path.getsize(path)
        out[cd] = {"build_s": round(build_s, 3), "t_apply_s": t,
                   "artifact_bytes": artifact_bytes,
                   "n_layers": len(dtypes),
                   "n_reduced_layers": (0 if cd == "float32" else
                                        sum(cd in d_ for d_ in dtypes)),
                   "rel_err_vs_fp32": round(rel, 6),
                   "top1_agreement": top1}
        print(f"  {cd:8s}: apply {t*1e3:7.2f}ms  artifact "
              f"{artifact_bytes>>10:6d}KiB  reduced layers "
              f"{out[cd]['n_reduced_layers']}/{len(dtypes)}  "
              f"top1 {top1:.3f}  rel_err {rel:.4f}", flush=True)
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_PR8.json")
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized run: half-resolution ladder, res-32 "
                         "network, fewer iters")
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--res", type=int, default=None,
                    help="network-pass input resolution "
                         "(default 32 quick / 96 full)")
    ap.add_argument("--inputs", type=int, default=16,
                    help="random inputs for the logits top-1 gate")
    ap.add_argument("--top1-threshold", type=float, default=0.75,
                    help="accuracy gate: exit non-zero when the int8 "
                         "network's top-1 agreement vs fp32 is below this "
                         "(the network is random-init, so logit margins "
                         "are near-noise -- trained networks agree far "
                         "more often at the same quantization error)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    scale = 2 if args.quick else 1
    iters = args.iters or (2 if args.quick else 3)
    res = args.res or (32 if args.quick else 96)

    t0 = time.time()
    layers, summary = run_layers(scale, iters, args.warmup)
    network = run_network(res, args.inputs, iters, args.warmup, args.seed)

    gate = {"int8_top1_agreement": network["int8"]["top1_agreement"],
            "threshold": args.top1_threshold,
            "passed": network["int8"]["top1_agreement"]
            >= args.top1_threshold}
    out = {"meta": bench_metadata(),
           "benchmark": "precision",
           "config": {"scale": scale, "iters": iters,
                      "warmup": args.warmup, "network_res": res,
                      "network_inputs": args.inputs,
                      "quick": args.quick, "seed": args.seed,
                      "accuracy_budget": dict(
                          planlib.AUTOTUNE_ACCURACY_BUDGET)},
           "layers": layers,
           "summary": summary,
           "network": network,
           "accuracy_gate": gate}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"\naccuracy gate: int8 top-1 agreement "
          f"{gate['int8_top1_agreement']:.3f} "
          f"(threshold {gate['threshold']}) "
          f"{'PASSED' if gate['passed'] else 'FAILED'}; "
          f"wrote {args.out} in {time.time() - t0:.0f}s", flush=True)
    if not gate["passed"]:
        raise SystemExit("precision accuracy gate FAILED (see JSON)")


if __name__ == "__main__":
    main()
