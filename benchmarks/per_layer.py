"""Paper Table 2: per-layer speedup of the region-wise multi-channel
Winograd/Cook-Toom scheme over the im2row GEMM baseline.

For every *unique* Winograd-suitable conv layer shape in the five paper
networks, times both schemes (jitted, batch 1 -- the paper's mobile-inference
setting) and reports average / peak speedup grouped by (model, layer type),
the exact structure of Table 2.

This is the same-backend CPU wall-time reproduction (DESIGN.md section 7):
both schemes run under identical XLA jit, so the ratio isolates the
algorithmic effect, as the paper's NEON-vs-NEON comparison does.
"""

from __future__ import annotations

import argparse
import functools
import json
import time
from collections import defaultdict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch
from repro.core import plan as planlib

from benchmarks.common import conv_layer_inventory, time_jitted

NETWORKS = ["vgg16", "vgg19", "googlenet", "inception_v3", "squeezenet"]


def _layer_type(kh: int, kw: int) -> str:
    return f"{kh}x{kw}"


@functools.partial(jax.jit, static_argnames=("kh", "kw", "c_out", "stride",
                                             "algorithm"))
def _run_layer(x, w, *, kh, kw, c_out, stride, algorithm):
    return dispatch.conv2d(x, w, stride=stride, algorithm=algorithm)


def bench_layer(layer: dict, iters: int, warmup: int) -> dict:
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(
        (1, layer["h"], layer["w"], layer["c_in"])), jnp.float32)
    wt = jnp.asarray(rng.standard_normal(
        (layer["kh"], layer["kw"], layer["c_in"], layer["c_out"]))
        / (layer["kh"] * layer["kw"]), jnp.float32)
    kw = dict(kh=layer["kh"], kw=layer["kw"], c_out=layer["c_out"],
              stride=layer["stride"])
    t_im2col = time_jitted(
        functools.partial(_run_layer, algorithm="im2col", **kw), x, wt,
        warmup=warmup, iters=iters)
    t_wino = time_jitted(
        functools.partial(_run_layer, algorithm="winograd", **kw), x, wt,
        warmup=warmup, iters=iters)
    # plan/execute split: filter transform + geometry decided once at plan
    # time; steady-state apply() is the paper's deployment-path number.
    t0 = time.perf_counter()
    p = planlib.plan_conv2d(x.shape, wt, stride=layer["stride"],
                            algorithm="winograd")
    jax.block_until_ready(p.u)
    plan_build = time.perf_counter() - t0
    t_wino_planned = time_jitted(jax.jit(p.apply), x,
                                 warmup=warmup, iters=iters)
    return {"t_im2col_s": t_im2col, "t_winograd_s": t_wino,
            "t_winograd_planned_s": t_wino_planned,
            "plan_build_s": plan_build,
            "speedup": t_im2col / t_wino,
            "speedup_planned": t_im2col / t_wino_planned}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--networks", nargs="*", default=NETWORKS)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--max-layers-per-net", type=int, default=0,
                    help="0 = all unique suitable layers")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    rows = []
    seen = set()
    for net in args.networks:
        layers = [l for l in conv_layer_inventory(net) if l["suitable"]]
        uniq = []
        for l in layers:
            key = (l["kh"], l["kw"], l["c_in"], l["c_out"], l["h"], l["w"])
            if key not in seen:
                seen.add(key)
                uniq.append(l)
        if args.max_layers_per_net:
            uniq = uniq[:args.max_layers_per_net]
        for l in uniq:
            r = bench_layer(l, args.iters, args.warmup)
            r.update(net=net, layer=l["name"],
                     ltype=_layer_type(l["kh"], l["kw"]),
                     shape=f"{l['h']}x{l['w']}x{l['c_in']}->{l['c_out']}")
            rows.append(r)
            print(f"{net:13s} {l['name']:12s} {r['ltype']:4s} {r['shape']:22s} "
                  f"im2col={r['t_im2col_s']*1e3:8.2f}ms "
                  f"wino={r['t_winograd_s']*1e3:8.2f}ms "
                  f"planned={r['t_winograd_planned_s']*1e3:8.2f}ms "
                  f"(build {r['plan_build_s']*1e3:6.1f}ms) "
                  f"speedup={r['speedup']:.2f}x/"
                  f"{r['speedup_planned']:.2f}x", flush=True)

    # Table 2 rollup: (model, layer-type) -> avg / peak speedup, for both the
    # per-call path and the planned (pre-transformed weights) path
    groups = defaultdict(list)
    for r in rows:
        groups[(r["net"], r["ltype"])].append(
            (r["speedup"], r["speedup_planned"]))
    print("\n== Table 2 reproduction: per-layer speedup (im2row vs ours) ==")
    print(f"{'Model':14s} {'Layer-type':10s} {'Avg':>6s} {'Peak':>6s} "
          f"{'AvgPl':>6s} {'PeakPl':>6s} {'n':>3s}")
    summary = []
    for (net, lt), pairs in sorted(groups.items()):
        sp = [a for a, _ in pairs]
        spp = [b for _, b in pairs]
        row = {"net": net, "ltype": lt, "avg_speedup": float(np.mean(sp)),
               "peak_speedup": float(np.max(sp)),
               "avg_speedup_planned": float(np.mean(spp)),
               "peak_speedup_planned": float(np.max(spp)),
               "n_layers": len(sp)}
        summary.append(row)
        print(f"{net:14s} {lt:10s} {row['avg_speedup']:6.2f} "
              f"{row['peak_speedup']:6.2f} "
              f"{row['avg_speedup_planned']:6.2f} "
              f"{row['peak_speedup_planned']:6.2f} {len(sp):3d}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"layers": rows, "summary": summary}, f, indent=1)
    return summary


if __name__ == "__main__":
    main()
