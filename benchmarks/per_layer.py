"""Paper Table 2: per-layer speedup of the region-wise multi-channel
Winograd/Cook-Toom scheme over the im2row GEMM baseline.

For every *unique* Winograd-suitable conv layer shape in the five paper
networks, times both schemes (jitted, batch 1 -- the paper's mobile-inference
setting) and reports average / peak speedup grouped by (model, layer type),
the exact structure of Table 2.

This is the same-backend CPU wall-time reproduction (DESIGN.md section 7):
both schemes run under identical XLA jit, so the ratio isolates the
algorithmic effect, as the paper's NEON-vs-NEON comparison does.
"""

from __future__ import annotations

import argparse
import functools
import json
import time
from collections import defaultdict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch
from repro.core import plan as planlib

from benchmarks.common import (bench_metadata, conv_layer_inventory,
                               materialized_hbm_bytes, pairwise_min_times,
                               pallas_im2row_hbm_bytes,
                               separable_fused_hbm_bytes,
                               separable_unfused_hbm_bytes,
                               streamed_hbm_bytes,
                               strided_streamed_hbm_bytes, time_jitted)

NETWORKS = ["vgg16", "vgg19", "googlenet", "inception_v3", "squeezenet"]

#: The unique 3x3 stride-1 conv shapes of VGG-16 at paper resolution --
#: the "VGG-style config" the streaming-vs-materialized Pallas A/B runs on
#: (BENCH_PR2.json; EXPERIMENTS.md section Perf). `vgg_style_quick` is the
#: same ladder at half spatial size for CI.
VGG_STYLE_LAYERS = [
    dict(name="conv1_1", kh=3, kw=3, h=224, w=224, c_in=3, c_out=64),
    dict(name="conv1_2", kh=3, kw=3, h=224, w=224, c_in=64, c_out=64),
    dict(name="conv2_1", kh=3, kw=3, h=112, w=112, c_in=64, c_out=128),
    dict(name="conv2_2", kh=3, kw=3, h=112, w=112, c_in=128, c_out=128),
    dict(name="conv3_1", kh=3, kw=3, h=56, w=56, c_in=128, c_out=256),
    dict(name="conv3_2", kh=3, kw=3, h=56, w=56, c_in=256, c_out=256),
    dict(name="conv4_1", kh=3, kw=3, h=28, w=28, c_in=256, c_out=512),
    dict(name="conv4_2", kh=3, kw=3, h=28, w=28, c_in=512, c_out=512),
    dict(name="conv5_1", kh=3, kw=3, h=14, w=14, c_in=512, c_out=512),
]


def vgg_style_layers(scale: int = 1) -> list[dict]:
    out = []
    for l in VGG_STYLE_LAYERS:
        l = dict(l, h=max(l["h"] // scale, 8), w=max(l["w"] // scale, 8),
                 stride=1)
        out.append(l)
    return out


#: The stride-1 depthwise-separable block shapes of MobileNet-v1 at paper
#: resolution -- the "mobilenet config" ladder the separable-block A/B runs
#: on (BENCH_PR3.json). Each row is one SeparableConv: a 3x3 depthwise conv
#: (groups = C_in, multiplier 1) followed by a 1x1 pointwise conv.
#: `mobilenet_quick` halves the spatial size for CI.
MOBILENET_LAYERS = [
    dict(name="sep2", k=3, h=112, w=112, c_in=32, c_out=64),
    dict(name="sep4", k=3, h=56, w=56, c_in=128, c_out=128),
    dict(name="sep6", k=3, h=28, w=28, c_in=256, c_out=256),
    dict(name="sep8", k=3, h=14, w=14, c_in=512, c_out=512),
    dict(name="sep14", k=3, h=7, w=7, c_in=1024, c_out=1024),
]


def mobilenet_layers(scale: int = 1) -> list[dict]:
    return scaled(MOBILENET_LAYERS, scale)


#: The stride-2 reduction blocks of MobileNet-v1 at paper resolution -- the
#: ladder the stride-2 Winograd (transform-domain phase decomposition) A/B
#: runs on (BENCH_PR4.json). Each row benchmarks the dense 3x3 stride-2
#: shape (strided streaming Pallas kernel vs the Pallas im2row baseline,
#: plus the XLA winograd_strided vs im2row A/B) and the depthwise stride-2
#: layer (XLA strided Winograd vs grouped im2row).
MOBILENET_REDUCTION_LAYERS = [
    dict(name="sep3_s2", k=3, h=112, w=112, c_in=64, c_out=128),
    dict(name="sep5_s2", k=3, h=56, w=56, c_in=128, c_out=256),
    dict(name="sep7_s2", k=3, h=28, w=28, c_in=256, c_out=512),
    dict(name="sep12_s2", k=3, h=14, w=14, c_in=512, c_out=1024),
]

#: MobileNet-v2 stride-1 inverted-residual shapes (expand 6) for the fused
#: (expand GEMM + ONE streamed separable kernel) vs composed (three Pallas
#: plans, intermediates via HBM) A/B.
MOBILENET_V2_LAYERS = [
    dict(name="ir4", h=28, w=28, c_in=32, expand=6),
    dict(name="ir11", h=14, w=14, c_in=96, expand=6),
]


def scaled(layers: list[dict], scale: int) -> list[dict]:
    if scale == 1:
        return [dict(l) for l in layers]
    return [dict(l, h=max(l["h"] // scale, 8), w=max(l["w"] // scale, 8))
            for l in layers]


def crossover_layers(scale: int = 1) -> list[dict]:
    """The N-way auto_tuned crossover ladder (BENCH_PR6.json): a filter-size
    x resolution x channel grid where the im2row / F(2,3)/F(4,3) / F(6,3) /
    FFT crossovers live, plus the VGG 3x3 ladder and the MobileNet-v2
    inverted-residual depthwise convs (groups = C, where the race is
    winograd_depthwise vs grouped im2row)."""
    grid = [dict(name=f"g{k}x{k}_{r}_{c}", kh=k, kw=k, h=r, w=r,
                 c_in=c, c_out=c, stride=1)
            for k in (3, 5, 7) for r in (14, 28, 56) for c in (32, 128)]
    vgg = [dict(l, stride=1) for l in VGG_STYLE_LAYERS]
    mbv2 = []
    for l in MOBILENET_V2_LAYERS:
        ce = l["c_in"] * l["expand"]
        mbv2.append(dict(name=f"{l['name']}_dw", kh=3, kw=3, h=l["h"],
                         w=l["w"], c_in=ce, c_out=ce, stride=1, groups=ce))
    return scaled(grid + vgg + mbv2, scale)


def bench_layer_crossover(layer: dict, iters: int, warmup: int) -> dict:
    """Plan the layer with algorithm='auto_tuned' (the plan-time N-way
    measured race runs here, once), then re-time the chosen plan end to end.
    The per-contender evidence is read back from the plan's autotune report
    -- the same record persisted into NetworkPlan artifacts."""
    rng = np.random.default_rng(0)
    groups = layer.get("groups", 1)
    x = jnp.asarray(rng.standard_normal(
        (1, layer["h"], layer["w"], layer["c_in"])), jnp.float32)
    wt = jnp.asarray(rng.standard_normal(
        (layer["kh"], layer["kw"], layer["c_in"] // groups,
         layer["c_out"])) / (layer["kh"] * layer["kw"]), jnp.float32)
    p = planlib.plan_conv2d(x.shape, wt, stride=layer["stride"],
                            algorithm="auto_tuned", groups=groups)
    report = p.spec.autotune_report or {}
    evidence = {k: v for k, v in report.items() if k.startswith("t_")}
    best_single = min(evidence.values()) if evidence else None
    t_winner = evidence.get(_winner_evidence_key(report, evidence))
    t_policy = time_jitted(jax.jit(p.apply), x, warmup=warmup, iters=iters)
    return {"algorithm": p.spec.algorithm,
            "tile": list(p.spec.output_tile) if p.spec.output_tile else None,
            "decision": p.describe()["decision"],
            "t_policy_s": t_policy, "evidence": evidence,
            "t_best_single_s": best_single, "t_winner_s": t_winner,
            "policy_matches_best": (best_single is None
                                    or t_winner <= best_single)}


def _winner_evidence_key(report: dict, evidence: dict) -> str | None:
    """Evidence key of the winning contender (winner_label names the
    contender; the winner field names the resolved executor)."""
    lbl = report.get("winner_label")
    if lbl is None or not evidence:
        return None
    return f"t_{lbl}_s"


def run_crossover(args) -> tuple[list, list]:
    scale = 2 if args.config.endswith("_quick") else 1
    rows = []
    print(f"== N-way auto_tuned crossover ladder ({args.config}) ==")
    for l in crossover_layers(scale):
        r = bench_layer_crossover(l, args.iters, args.warmup)
        r.update(layer=l["name"], ltype=_layer_type(l["kh"], l["kw"]),
                 shape=f"{l['h']}x{l['w']}x{l['c_in']}->{l['c_out']}"
                       + (f"/g{l['groups']}" if l.get("groups", 1) > 1
                          else ""))
        rows.append(r)
        print(f"{l['name']:14s} {r['ltype']:4s} {r['shape']:24s} "
              f"-> {r['algorithm']:22s} "
              f"policy={r['t_policy_s']*1e3:8.2f}ms "
              f"best_single={r['t_best_single_s']*1e3 if r['t_best_single_s'] else 0:8.2f}ms "
              f"({r['decision']})", flush=True)
    winners = defaultdict(int)
    for r in rows:
        winners[r["algorithm"]] += 1
    summary = [{
        "config": args.config, "n_layers": len(rows),
        "winners": dict(winners),
        "n_measured": sum(r["decision"] == "measured" for r in rows),
        "policy_matches_best_all": bool(all(r["policy_matches_best"]
                                            for r in rows)),
    }]
    print(f"\n== crossover summary ==")
    print(f"winners: {dict(winners)}  measured: {summary[0]['n_measured']}"
          f"/{len(rows)}  policy matches best single algorithm on all "
          f"layers: {summary[0]['policy_matches_best_all']}")
    return rows, summary


def bench_layer_pallas(layer: dict, iters: int, warmup: int) -> dict:
    """Streamed (halo-streaming kernel, fused bias+relu epilogue) vs the
    pre-streaming planned Pallas path (materialized tiles + un-tiling pass +
    XLA bias/relu), interleaved best-of timing plus the analytic HBM bytes
    each path moves."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(
        (1, layer["h"], layer["w"], layer["c_in"])), jnp.float32)
    wt = jnp.asarray(rng.standard_normal(
        (layer["kh"], layer["kw"], layer["c_in"], layer["c_out"]))
        / (layer["kh"] * layer["kw"]), jnp.float32)
    b = jnp.asarray(rng.standard_normal((layer["c_out"],)), jnp.float32)
    t0 = time.perf_counter()
    p_new = planlib.plan_conv2d(x.shape, wt, algorithm="pallas_winograd")
    jax.block_until_ready(p_new.u)
    plan_build = time.perf_counter() - t0
    p_old = planlib.plan_conv2d(x.shape, wt,
                                algorithm="pallas_winograd_materialized")
    f_new = jax.jit(lambda x: p_new.apply(x, bias=b, activation="relu"))
    f_old = jax.jit(lambda x: jax.nn.relu(p_old.apply(x) + b))
    t_new, t_old = pairwise_min_times(f_new, f_old, x, warmup=warmup,
                                      iters=iters)
    by_new = streamed_hbm_bytes(p_new.spec)
    by_old = materialized_hbm_bytes(p_old.spec)
    s = p_new.spec.stream
    return {"t_pallas_streamed_s": t_new, "t_pallas_old_s": t_old,
            "speedup_streamed": t_old / t_new,
            "hbm_bytes_streamed": by_new, "hbm_bytes_materialized": by_old,
            "hbm_bytes_ratio": by_old / by_new,
            "plan_build_s": plan_build,
            "stream_blocks": [s.bh, s.bw, s.block_c, s.block_m]}


def run_vgg_style(args) -> tuple[list[dict], list[dict]]:
    layers = vgg_style_layers(scale=2 if args.config == "vgg_style_quick"
                              else 1)
    rows = []
    for l in layers:
        r = bench_layer_pallas(l, args.iters, args.warmup)
        r.update(net="vgg_style", layer=l["name"],
                 ltype=_layer_type(l["kh"], l["kw"]),
                 shape=f"{l['h']}x{l['w']}x{l['c_in']}->{l['c_out']}")
        rows.append(r)
        print(f"{l['name']:10s} {r['shape']:22s} "
              f"streamed={r['t_pallas_streamed_s']*1e3:8.2f}ms "
              f"old={r['t_pallas_old_s']*1e3:8.2f}ms "
              f"speedup={r['speedup_streamed']:.2f}x "
              f"bytes {r['hbm_bytes_streamed']/2**20:7.1f}MiB vs "
              f"{r['hbm_bytes_materialized']/2**20:7.1f}MiB "
              f"({r['hbm_bytes_ratio']:.2f}x)", flush=True)
    sp = [r["speedup_streamed"] for r in rows]
    br = [r["hbm_bytes_ratio"] for r in rows]
    summary = [{"net": "vgg_style", "ltype": "3x3",
                "avg_speedup_streamed": float(np.mean(sp)),
                "min_speedup_streamed": float(np.min(sp)),
                "avg_hbm_bytes_ratio": float(np.mean(br)),
                "n_layers": len(rows)}]
    print(f"\n== streaming vs materialized Pallas path ({args.config}) ==")
    print(f"avg speedup {summary[0]['avg_speedup_streamed']:.2f}x  "
          f"min {summary[0]['min_speedup_streamed']:.2f}x  "
          f"avg HBM-bytes ratio {summary[0]['avg_hbm_bytes_ratio']:.2f}x")
    return rows, summary


def bench_layer_mobilenet(layer: dict, iters: int, warmup: int) -> dict:
    """One MobileNet separable block, three A/Bs:

      * depthwise layer alone, same XLA backend: transform-domain-Hadamard
        depthwise Winograd vs the grouped im2row GEMM baseline;
      * whole block, same Pallas backend: the FUSED separable streamed
        kernel (one kernel, intermediate in VMEM, both epilogues in-kernel)
        vs the UNFUSED pipeline (streamed depthwise kernel + pointwise GEMM
        kernel, intermediate round-tripping HBM) -- interleaved best-of
        timing plus the analytic HBM bytes each path moves;
      * whole block, unfused grouped-im2row XLA reference (the dense-only
        repo's best pre-PR3 answer for a separable block).
    """
    rng = np.random.default_rng(0)
    c, m = layer["c_in"], layer["c_out"]
    x = jnp.asarray(rng.standard_normal(
        (1, layer["h"], layer["w"], c)), jnp.float32)
    w_dw = jnp.asarray(rng.standard_normal((layer["k"], layer["k"], 1, c))
                       / layer["k"] ** 2, jnp.float32)
    w_pw = jnp.asarray(rng.standard_normal((1, 1, c, m)) / np.sqrt(c),
                       jnp.float32)
    b_dw = jnp.asarray(rng.standard_normal((c,)), jnp.float32)
    b_pw = jnp.asarray(rng.standard_normal((m,)), jnp.float32)

    # depthwise layer alone: Winograd (Hadamard phase 2) vs grouped im2row.
    p_dw_wino = planlib.plan_conv2d(x.shape, w_dw, groups=c,
                                    algorithm="winograd")
    p_dw_im2row = planlib.plan_conv2d(x.shape, w_dw, groups=c,
                                      algorithm="im2col")
    t_dw_wino, t_dw_im2row = pairwise_min_times(
        jax.jit(p_dw_wino.apply), jax.jit(p_dw_im2row.apply), x,
        warmup=warmup, iters=iters)

    # whole block, Pallas: fused separable kernel vs unfused two-kernel
    # pipeline (intermediate via HBM).
    t0 = time.perf_counter()
    p_fused = planlib.plan_separable_block(x.shape, w_dw, w_pw,
                                           algorithm="pallas_winograd")
    jax.block_until_ready(p_fused.u_pw)
    plan_build = time.perf_counter() - t0
    assert p_fused.mode == "fused_pallas", p_fused.mode
    p_dw_pallas = planlib.plan_conv2d(x.shape, w_dw, groups=c,
                                      algorithm="pallas_winograd")
    p_pw_pallas = planlib.plan_conv2d(p_dw_pallas.out_shape, w_pw,
                                      algorithm="pallas_im2col")
    f_fused = jax.jit(lambda x: p_fused.apply(x, bias_dw=b_dw, bias_pw=b_pw))
    f_unfused = jax.jit(lambda x: p_pw_pallas.apply(
        p_dw_pallas.apply(x, bias=b_dw, activation="relu"),
        bias=b_pw, activation="relu"))
    t_fused, t_unfused = pairwise_min_times(f_fused, f_unfused, x,
                                            warmup=warmup, iters=iters)

    # whole block, unfused grouped-im2row XLA reference.
    p_pw_im2row = planlib.plan_conv2d(p_dw_im2row.out_shape, w_pw,
                                      algorithm="im2col")
    f_im2row = jax.jit(lambda x: p_pw_im2row.apply(
        p_dw_im2row.apply(x, bias=b_dw, activation="relu"),
        bias=b_pw, activation="relu"))
    t_block_im2row = time_jitted(f_im2row, x, warmup=warmup, iters=iters)

    oh, ow = p_dw_pallas.out_shape[1:3]
    by_fused = separable_fused_hbm_bytes(p_fused.spec)
    by_unfused = separable_unfused_hbm_bytes(
        p_dw_pallas.spec, pw_mm=oh * ow, pw_k=c, pw_n=m,
        blocks=p_pw_pallas.spec.blocks)
    s = p_fused.spec.stream
    return {"t_dw_winograd_s": t_dw_wino, "t_dw_im2row_s": t_dw_im2row,
            "speedup_dw": t_dw_im2row / t_dw_wino,
            "t_sep_fused_s": t_fused, "t_sep_unfused_s": t_unfused,
            "speedup_fused": t_unfused / t_fused,
            "t_sep_im2row_xla_s": t_block_im2row,
            "hbm_bytes_fused": by_fused, "hbm_bytes_unfused": by_unfused,
            "hbm_bytes_ratio": by_unfused / by_fused,
            "plan_build_s": plan_build,
            "stream_blocks": [s.bh, s.bw, s.block_c, s.block_m]}


def bench_layer_reduction(layer: dict, iters: int, warmup: int) -> dict:
    """One stride-2 reduction-block shape, three A/Bs:

      * dense 3x3 stride-2, same Pallas backend: the strided streaming
        kernel (transform-domain phase decomposition, fused bias+relu) vs
        the Pallas im2row GEMM baseline (patch-matrix materialization +
        blocked GEMM, fused epilogue) -- interleaved best-of timing plus the
        analytic HBM bytes each path moves;
      * dense 3x3 stride-2, same XLA backend: winograd_strided vs im2row;
      * depthwise 3x3 stride-2 (the actual MobileNet reduction layer), same
        XLA backend: strided Winograd (Hadamard phase 2) vs grouped im2row.
    """
    rng = np.random.default_rng(0)
    c, m = layer["c_in"], layer["c_out"]
    x = jnp.asarray(rng.standard_normal(
        (1, layer["h"], layer["w"], c)), jnp.float32)
    w_dense = jnp.asarray(rng.standard_normal((layer["k"], layer["k"], c, m))
                          / layer["k"] ** 2, jnp.float32)
    w_dw = jnp.asarray(rng.standard_normal((layer["k"], layer["k"], 1, c))
                       / layer["k"] ** 2, jnp.float32)
    b = jnp.asarray(rng.standard_normal((m,)), jnp.float32)

    # dense, Pallas backend: strided streaming kernel vs im2row GEMM kernel.
    t0 = time.perf_counter()
    p_strided = planlib.plan_conv2d(x.shape, w_dense, stride=2,
                                    algorithm="pallas_winograd")
    jax.block_until_ready(p_strided.u)
    plan_build = time.perf_counter() - t0
    assert p_strided.algorithm == "pallas_winograd_strided"
    p_im2row_pl = planlib.plan_conv2d(x.shape, w_dense, stride=2,
                                      algorithm="pallas_im2col")
    f_strided = jax.jit(lambda x: p_strided.apply(x, bias=b,
                                                  activation="relu"))
    f_im2row_pl = jax.jit(lambda x: p_im2row_pl.apply(x, bias=b,
                                                      activation="relu"))
    t_strided, t_im2row_pl = pairwise_min_times(f_strided, f_im2row_pl, x,
                                                warmup=warmup, iters=iters)

    # dense, XLA backend.
    p_xw = planlib.plan_conv2d(x.shape, w_dense, stride=2,
                               algorithm="winograd")
    p_xi = planlib.plan_conv2d(x.shape, w_dense, stride=2,
                               algorithm="im2col")
    t_xla_wino, t_xla_im2row = pairwise_min_times(
        jax.jit(p_xw.apply), jax.jit(p_xi.apply), x,
        warmup=warmup, iters=iters)

    # depthwise stride-2 (the real reduction layer), XLA backend.
    p_dw_w = planlib.plan_conv2d(x.shape, w_dw, stride=2, groups=c,
                                 algorithm="winograd")
    p_dw_i = planlib.plan_conv2d(x.shape, w_dw, stride=2, groups=c,
                                 algorithm="im2col")
    t_dw_wino, t_dw_im2row = pairwise_min_times(
        jax.jit(p_dw_w.apply), jax.jit(p_dw_i.apply), x,
        warmup=warmup, iters=iters)

    by_strided = strided_streamed_hbm_bytes(p_strided.spec)
    by_im2row = pallas_im2row_hbm_bytes(p_im2row_pl.spec)
    s = p_strided.spec.stream
    return {"t_pallas_strided_s": t_strided,
            "t_pallas_im2row_s": t_im2row_pl,
            "speedup_strided": t_im2row_pl / t_strided,
            "t_xla_strided_wino_s": t_xla_wino,
            "t_xla_im2row_s": t_xla_im2row,
            "speedup_xla": t_xla_im2row / t_xla_wino,
            "t_dw_strided_wino_s": t_dw_wino, "t_dw_im2row_s": t_dw_im2row,
            "speedup_dw": t_dw_im2row / t_dw_wino,
            "hbm_bytes_strided": by_strided, "hbm_bytes_im2row": by_im2row,
            "hbm_bytes_ratio": by_im2row / by_strided,
            "plan_build_s": plan_build,
            "output_tile": list(p_strided.spec.output_tile),
            "stream_blocks": [s.bh, s.bw, s.block_c, s.block_m]}


def bench_layer_mbv2(layer: dict, iters: int, warmup: int) -> dict:
    """One stride-1 MobileNet-v2 inverted-residual block, same Pallas
    backend: the FUSED plan (expand GEMM + ONE streamed separable kernel,
    depthwise->project intermediate in VMEM, residual add) vs the COMPOSED
    pipeline (expand GEMM + streamed depthwise kernel + Pallas pointwise
    GEMM, intermediates round-tripping HBM)."""
    rng = np.random.default_rng(0)
    c, t = layer["c_in"], layer["expand"]
    ce = c * t
    x = jnp.asarray(rng.standard_normal(
        (1, layer["h"], layer["w"], c)), jnp.float32)
    w_exp = jnp.asarray(rng.standard_normal((1, 1, c, ce)) / np.sqrt(c),
                        jnp.float32)
    w_dw = jnp.asarray(rng.standard_normal((3, 3, 1, ce)) / 9, jnp.float32)
    w_pw = jnp.asarray(rng.standard_normal((1, 1, ce, c)) / np.sqrt(ce),
                       jnp.float32)
    b_exp = jnp.asarray(rng.standard_normal((ce,)), jnp.float32)
    b_dw = jnp.asarray(rng.standard_normal((ce,)), jnp.float32)
    b_pw = jnp.asarray(rng.standard_normal((c,)), jnp.float32)

    t0 = time.perf_counter()
    p_fused = planlib.plan_inverted_residual(
        x.shape, w_exp, w_dw, w_pw, stride=1, algorithm="pallas_winograd")
    jax.block_until_ready(p_fused.sep.u_pw)
    plan_build = time.perf_counter() - t0
    assert p_fused.mode == "fused_pallas", p_fused.mode
    f_fused = jax.jit(lambda x: p_fused.apply(
        x, bias_exp=b_exp, bias_dw=b_dw, bias_pw=b_pw))

    p_exp = planlib.plan_conv2d(x.shape, w_exp, algorithm="im2col")
    p_dw = planlib.plan_conv2d(p_exp.out_shape, w_dw, groups=ce,
                               algorithm="pallas_winograd")
    p_pw = planlib.plan_conv2d(p_dw.out_shape, w_pw,
                               algorithm="pallas_im2col")

    def composed(x):
        h = p_exp.apply(x, bias=b_exp, activation="relu6")
        h = p_dw.apply(h, bias=b_dw, activation="relu6")
        return x + p_pw.apply(h, bias=b_pw, activation="none")

    t_fused, t_composed = pairwise_min_times(f_fused, jax.jit(composed), x,
                                             warmup=warmup, iters=iters)
    return {"t_mbv2_fused_s": t_fused, "t_mbv2_composed_s": t_composed,
            "speedup_fused": t_composed / t_fused,
            "plan_build_s": plan_build}


def run_mobilenet(args) -> tuple[list[dict], list[dict]]:
    scale = 2 if args.config == "mobilenet_quick" else 1
    layers = mobilenet_layers(scale=scale)
    rows = []
    for l in layers:
        r = bench_layer_mobilenet(l, args.iters, args.warmup)
        r.update(net="mobilenet_v1", layer=l["name"], ltype="sep3x3",
                 shape=f"{l['h']}x{l['w']}x{l['c_in']}->{l['c_out']}")
        rows.append(r)
        print(f"{l['name']:8s} {r['shape']:22s} "
              f"fused={r['t_sep_fused_s']*1e3:8.2f}ms "
              f"unfused={r['t_sep_unfused_s']*1e3:8.2f}ms "
              f"speedup={r['speedup_fused']:.2f}x "
              f"dw wino/im2row={r['speedup_dw']:.2f}x "
              f"bytes {r['hbm_bytes_fused']/2**20:6.1f}MiB vs "
              f"{r['hbm_bytes_unfused']/2**20:6.1f}MiB "
              f"({r['hbm_bytes_ratio']:.2f}x)", flush=True)
    sp = [r["speedup_fused"] for r in rows]
    sd = [r["speedup_dw"] for r in rows]
    br = [r["hbm_bytes_ratio"] for r in rows]
    summary = [{"net": "mobilenet_v1", "ltype": "sep3x3",
                "avg_speedup_fused": float(np.mean(sp)),
                "min_speedup_fused": float(np.min(sp)),
                "avg_speedup_dw": float(np.mean(sd)),
                "avg_hbm_bytes_ratio": float(np.mean(br)),
                "n_layers": len(rows)}]
    print(f"\n== fused separable block vs unfused baseline "
          f"({args.config}) ==")
    print(f"avg speedup {summary[0]['avg_speedup_fused']:.2f}x  "
          f"min {summary[0]['min_speedup_fused']:.2f}x  "
          f"avg dw wino/im2row {summary[0]['avg_speedup_dw']:.2f}x  "
          f"avg HBM-bytes ratio {summary[0]['avg_hbm_bytes_ratio']:.2f}x")

    # stride-2 reduction-block ladder: strided Winograd vs im2row A/Bs.
    red_rows = []
    for l in scaled(MOBILENET_REDUCTION_LAYERS, scale):
        r = bench_layer_reduction(l, args.iters, args.warmup)
        r.update(net="mobilenet_v1", layer=l["name"], ltype="3x3s2",
                 shape=f"{l['h']}x{l['w']}x{l['c_in']}->{l['c_out']}")
        red_rows.append(r)
        print(f"{l['name']:9s} {r['shape']:22s} "
              f"pallas strided={r['t_pallas_strided_s']*1e3:8.2f}ms "
              f"im2row={r['t_pallas_im2row_s']*1e3:8.2f}ms "
              f"speedup={r['speedup_strided']:.2f}x "
              f"(xla {r['speedup_xla']:.2f}x, dw {r['speedup_dw']:.2f}x) "
              f"bytes {r['hbm_bytes_strided']/2**20:6.1f}MiB vs "
              f"{r['hbm_bytes_im2row']/2**20:6.1f}MiB "
              f"({r['hbm_bytes_ratio']:.2f}x)", flush=True)
    ss = [r["speedup_strided"] for r in red_rows]
    tot_strided = sum(r["t_pallas_strided_s"] for r in red_rows)
    tot_im2row = sum(r["t_pallas_im2row_s"] for r in red_rows)
    summary.append({
        "net": "mobilenet_v1", "ltype": "3x3s2",
        "avg_speedup_strided": float(np.mean(ss)),
        "min_speedup_strided": float(np.min(ss)),
        "ladder_speedup_strided": float(tot_im2row / tot_strided),
        "avg_speedup_xla": float(np.mean([r["speedup_xla"]
                                          for r in red_rows])),
        "avg_speedup_dw": float(np.mean([r["speedup_dw"]
                                         for r in red_rows])),
        "avg_hbm_bytes_ratio": float(np.mean([r["hbm_bytes_ratio"]
                                              for r in red_rows])),
        "n_layers": len(red_rows)})
    print(f"\n== stride-2 Winograd vs im2row, reduction ladder "
          f"({args.config}) ==")
    print(f"pallas avg {summary[-1]['avg_speedup_strided']:.2f}x  "
          f"min {summary[-1]['min_speedup_strided']:.2f}x  "
          f"whole-ladder {summary[-1]['ladder_speedup_strided']:.2f}x  "
          f"xla avg {summary[-1]['avg_speedup_xla']:.2f}x  "
          f"dw xla avg {summary[-1]['avg_speedup_dw']:.2f}x  "
          f"avg HBM-bytes ratio {summary[-1]['avg_hbm_bytes_ratio']:.2f}x")
    rows += red_rows

    # MobileNet-v2 inverted residual: fused vs composed, same backend.
    mb_rows = []
    for l in scaled(MOBILENET_V2_LAYERS, scale):
        r = bench_layer_mbv2(l, args.iters, args.warmup)
        r.update(net="mobilenet_v2", layer=l["name"], ltype="invres",
                 shape=f"{l['h']}x{l['w']}x{l['c_in']}(x{l['expand']})")
        mb_rows.append(r)
        print(f"{l['name']:9s} {r['shape']:22s} "
              f"fused={r['t_mbv2_fused_s']*1e3:8.2f}ms "
              f"composed={r['t_mbv2_composed_s']*1e3:8.2f}ms "
              f"speedup={r['speedup_fused']:.2f}x", flush=True)
    summary.append({
        "net": "mobilenet_v2", "ltype": "invres",
        "avg_speedup_fused": float(np.mean([r["speedup_fused"]
                                            for r in mb_rows])),
        "min_speedup_fused": float(np.min([r["speedup_fused"]
                                           for r in mb_rows])),
        "n_layers": len(mb_rows)})
    print(f"\n== MBv2 fused vs composed inverted residual "
          f"({args.config}) ==")
    print(f"avg speedup {summary[-1]['avg_speedup_fused']:.2f}x  "
          f"min {summary[-1]['min_speedup_fused']:.2f}x")
    rows += mb_rows
    return rows, summary


def _layer_type(kh: int, kw: int) -> str:
    return f"{kh}x{kw}"


@functools.partial(jax.jit, static_argnames=("kh", "kw", "c_out", "stride",
                                             "algorithm", "groups"))
def _run_layer(x, w, *, kh, kw, c_out, stride, algorithm, groups=1):
    return dispatch.conv2d(x, w, stride=stride, algorithm=algorithm,
                           groups=groups)


def bench_layer(layer: dict, iters: int, warmup: int) -> dict:
    rng = np.random.default_rng(0)
    groups = layer.get("groups", 1)
    x = jnp.asarray(rng.standard_normal(
        (1, layer["h"], layer["w"], layer["c_in"])), jnp.float32)
    wt = jnp.asarray(rng.standard_normal(
        (layer["kh"], layer["kw"], layer["c_in"] // groups,
         layer["c_out"])) / (layer["kh"] * layer["kw"]), jnp.float32)
    kw = dict(kh=layer["kh"], kw=layer["kw"], c_out=layer["c_out"],
              stride=layer["stride"], groups=groups)
    t_im2col = time_jitted(
        functools.partial(_run_layer, algorithm="im2col", **kw), x, wt,
        warmup=warmup, iters=iters)
    t_wino = time_jitted(
        functools.partial(_run_layer, algorithm="winograd", **kw), x, wt,
        warmup=warmup, iters=iters)
    # plan/execute split: filter transform + geometry decided once at plan
    # time; steady-state apply() is the paper's deployment-path number.
    t0 = time.perf_counter()
    p = planlib.plan_conv2d(x.shape, wt, stride=layer["stride"],
                            algorithm="winograd", groups=groups)
    jax.block_until_ready(p.u)
    plan_build = time.perf_counter() - t0
    t_wino_planned = time_jitted(jax.jit(p.apply), x,
                                 warmup=warmup, iters=iters)
    return {"t_im2col_s": t_im2col, "t_winograd_s": t_wino,
            "t_winograd_planned_s": t_wino_planned,
            "plan_build_s": plan_build,
            "speedup": t_im2col / t_wino,
            "speedup_planned": t_im2col / t_wino_planned}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--networks", nargs="*", default=NETWORKS)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--max-layers-per-net", type=int, default=0,
                    help="0 = all unique suitable layers")
    ap.add_argument("--config", default="paper",
                    choices=["paper", "vgg_style", "vgg_style_quick",
                             "mobilenet", "mobilenet_quick",
                             "crossover", "crossover_quick"],
                    help="paper: Table-2 sweep over the five networks; "
                         "vgg_style[_quick]: streamed-vs-materialized "
                         "Pallas A/B on the VGG 3x3 stride-1 ladder; "
                         "mobilenet[_quick]: fused-vs-unfused separable-"
                         "block A/B on the MobileNet-v1 stride-1 ladder; "
                         "crossover[_quick]: the N-way measured auto_tuned "
                         "race (im2row/F(2,3)/F(4,3)/F(6,3)/FFT) over the "
                         "filter-size x resolution x channel grid plus the "
                         "VGG and MobileNet-v2 ladders (BENCH_PR6.json)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    if args.config != "paper":
        if args.config.startswith("mobilenet"):
            rows, summary = run_mobilenet(args)
        elif args.config.startswith("crossover"):
            rows, summary = run_crossover(args)
        else:
            rows, summary = run_vgg_style(args)
        if args.out:
            with open(args.out, "w") as f:
                json.dump({"config": args.config, "meta": bench_metadata(),
                           "layers": rows, "summary": summary}, f, indent=1)
        return summary

    rows = []
    seen = set()
    for net in args.networks:
        layers = [l for l in conv_layer_inventory(net) if l["suitable"]]
        uniq = []
        for l in layers:
            key = (l["kh"], l["kw"], l["c_in"], l["c_out"], l["h"], l["w"],
                   l.get("groups", 1))
            if key not in seen:
                seen.add(key)
                uniq.append(l)
        if args.max_layers_per_net:
            uniq = uniq[:args.max_layers_per_net]
        for l in uniq:
            r = bench_layer(l, args.iters, args.warmup)
            r.update(net=net, layer=l["name"],
                     ltype=_layer_type(l["kh"], l["kw"]),
                     shape=f"{l['h']}x{l['w']}x{l['c_in']}->{l['c_out']}")
            rows.append(r)
            print(f"{net:13s} {l['name']:12s} {r['ltype']:4s} {r['shape']:22s} "
                  f"im2col={r['t_im2col_s']*1e3:8.2f}ms "
                  f"wino={r['t_winograd_s']*1e3:8.2f}ms "
                  f"planned={r['t_winograd_planned_s']*1e3:8.2f}ms "
                  f"(build {r['plan_build_s']*1e3:6.1f}ms) "
                  f"speedup={r['speedup']:.2f}x/"
                  f"{r['speedup_planned']:.2f}x", flush=True)

    # Table 2 rollup: (model, layer-type) -> avg / peak speedup, for both the
    # per-call path and the planned (pre-transformed weights) path
    groups = defaultdict(list)
    for r in rows:
        groups[(r["net"], r["ltype"])].append(
            (r["speedup"], r["speedup_planned"]))
    print("\n== Table 2 reproduction: per-layer speedup (im2row vs ours) ==")
    print(f"{'Model':14s} {'Layer-type':10s} {'Avg':>6s} {'Peak':>6s} "
          f"{'AvgPl':>6s} {'PeakPl':>6s} {'n':>3s}")
    summary = []
    for (net, lt), pairs in sorted(groups.items()):
        sp = [a for a, _ in pairs]
        spp = [b for _, b in pairs]
        row = {"net": net, "ltype": lt, "avg_speedup": float(np.mean(sp)),
               "peak_speedup": float(np.max(sp)),
               "avg_speedup_planned": float(np.mean(spp)),
               "peak_speedup_planned": float(np.max(spp)),
               "n_layers": len(sp)}
        summary.append(row)
        print(f"{net:14s} {lt:10s} {row['avg_speedup']:6.2f} "
              f"{row['peak_speedup']:6.2f} "
              f"{row['avg_speedup_planned']:6.2f} "
              f"{row['peak_speedup_planned']:6.2f} {len(sp):3d}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"meta": bench_metadata(), "layers": rows,
                       "summary": summary}, f, indent=1)
    return summary


if __name__ == "__main__":
    main()
