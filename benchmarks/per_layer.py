"""Paper Table 2: per-layer speedup of the region-wise multi-channel
Winograd/Cook-Toom scheme over the im2row GEMM baseline.

For every *unique* Winograd-suitable conv layer shape in the five paper
networks, times both schemes (jitted, batch 1 -- the paper's mobile-inference
setting) and reports average / peak speedup grouped by (model, layer type),
the exact structure of Table 2.

This is the same-backend CPU wall-time reproduction (DESIGN.md section 7):
both schemes run under identical XLA jit, so the ratio isolates the
algorithmic effect, as the paper's NEON-vs-NEON comparison does.
"""

from __future__ import annotations

import argparse
import functools
import json
import time
from collections import defaultdict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch
from repro.core import plan as planlib

from benchmarks.common import (conv_layer_inventory, materialized_hbm_bytes,
                               pairwise_min_times, streamed_hbm_bytes,
                               time_jitted)

NETWORKS = ["vgg16", "vgg19", "googlenet", "inception_v3", "squeezenet"]

#: The unique 3x3 stride-1 conv shapes of VGG-16 at paper resolution --
#: the "VGG-style config" the streaming-vs-materialized Pallas A/B runs on
#: (BENCH_PR2.json; EXPERIMENTS.md section Perf). `vgg_style_quick` is the
#: same ladder at half spatial size for CI.
VGG_STYLE_LAYERS = [
    dict(name="conv1_1", kh=3, kw=3, h=224, w=224, c_in=3, c_out=64),
    dict(name="conv1_2", kh=3, kw=3, h=224, w=224, c_in=64, c_out=64),
    dict(name="conv2_1", kh=3, kw=3, h=112, w=112, c_in=64, c_out=128),
    dict(name="conv2_2", kh=3, kw=3, h=112, w=112, c_in=128, c_out=128),
    dict(name="conv3_1", kh=3, kw=3, h=56, w=56, c_in=128, c_out=256),
    dict(name="conv3_2", kh=3, kw=3, h=56, w=56, c_in=256, c_out=256),
    dict(name="conv4_1", kh=3, kw=3, h=28, w=28, c_in=256, c_out=512),
    dict(name="conv4_2", kh=3, kw=3, h=28, w=28, c_in=512, c_out=512),
    dict(name="conv5_1", kh=3, kw=3, h=14, w=14, c_in=512, c_out=512),
]


def vgg_style_layers(scale: int = 1) -> list[dict]:
    out = []
    for l in VGG_STYLE_LAYERS:
        l = dict(l, h=max(l["h"] // scale, 8), w=max(l["w"] // scale, 8),
                 stride=1)
        out.append(l)
    return out


def bench_layer_pallas(layer: dict, iters: int, warmup: int) -> dict:
    """Streamed (halo-streaming kernel, fused bias+relu epilogue) vs the
    pre-streaming planned Pallas path (materialized tiles + un-tiling pass +
    XLA bias/relu), interleaved best-of timing plus the analytic HBM bytes
    each path moves."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(
        (1, layer["h"], layer["w"], layer["c_in"])), jnp.float32)
    wt = jnp.asarray(rng.standard_normal(
        (layer["kh"], layer["kw"], layer["c_in"], layer["c_out"]))
        / (layer["kh"] * layer["kw"]), jnp.float32)
    b = jnp.asarray(rng.standard_normal((layer["c_out"],)), jnp.float32)
    t0 = time.perf_counter()
    p_new = planlib.plan_conv2d(x.shape, wt, algorithm="pallas_winograd")
    jax.block_until_ready(p_new.u)
    plan_build = time.perf_counter() - t0
    p_old = planlib.plan_conv2d(x.shape, wt,
                                algorithm="pallas_winograd_materialized")
    f_new = jax.jit(lambda x: p_new.apply(x, bias=b, activation="relu"))
    f_old = jax.jit(lambda x: jax.nn.relu(p_old.apply(x) + b))
    t_new, t_old = pairwise_min_times(f_new, f_old, x, warmup=warmup,
                                      iters=iters)
    by_new = streamed_hbm_bytes(p_new.spec)
    by_old = materialized_hbm_bytes(p_old.spec)
    s = p_new.spec.stream
    return {"t_pallas_streamed_s": t_new, "t_pallas_old_s": t_old,
            "speedup_streamed": t_old / t_new,
            "hbm_bytes_streamed": by_new, "hbm_bytes_materialized": by_old,
            "hbm_bytes_ratio": by_old / by_new,
            "plan_build_s": plan_build,
            "stream_blocks": [s.bh, s.bw, s.block_c, s.block_m]}


def run_vgg_style(args) -> tuple[list[dict], list[dict]]:
    layers = vgg_style_layers(scale=2 if args.config == "vgg_style_quick"
                              else 1)
    rows = []
    for l in layers:
        r = bench_layer_pallas(l, args.iters, args.warmup)
        r.update(net="vgg_style", layer=l["name"],
                 ltype=_layer_type(l["kh"], l["kw"]),
                 shape=f"{l['h']}x{l['w']}x{l['c_in']}->{l['c_out']}")
        rows.append(r)
        print(f"{l['name']:10s} {r['shape']:22s} "
              f"streamed={r['t_pallas_streamed_s']*1e3:8.2f}ms "
              f"old={r['t_pallas_old_s']*1e3:8.2f}ms "
              f"speedup={r['speedup_streamed']:.2f}x "
              f"bytes {r['hbm_bytes_streamed']/2**20:7.1f}MiB vs "
              f"{r['hbm_bytes_materialized']/2**20:7.1f}MiB "
              f"({r['hbm_bytes_ratio']:.2f}x)", flush=True)
    sp = [r["speedup_streamed"] for r in rows]
    br = [r["hbm_bytes_ratio"] for r in rows]
    summary = [{"net": "vgg_style", "ltype": "3x3",
                "avg_speedup_streamed": float(np.mean(sp)),
                "min_speedup_streamed": float(np.min(sp)),
                "avg_hbm_bytes_ratio": float(np.mean(br)),
                "n_layers": len(rows)}]
    print(f"\n== streaming vs materialized Pallas path ({args.config}) ==")
    print(f"avg speedup {summary[0]['avg_speedup_streamed']:.2f}x  "
          f"min {summary[0]['min_speedup_streamed']:.2f}x  "
          f"avg HBM-bytes ratio {summary[0]['avg_hbm_bytes_ratio']:.2f}x")
    return rows, summary


def _layer_type(kh: int, kw: int) -> str:
    return f"{kh}x{kw}"


@functools.partial(jax.jit, static_argnames=("kh", "kw", "c_out", "stride",
                                             "algorithm"))
def _run_layer(x, w, *, kh, kw, c_out, stride, algorithm):
    return dispatch.conv2d(x, w, stride=stride, algorithm=algorithm)


def bench_layer(layer: dict, iters: int, warmup: int) -> dict:
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(
        (1, layer["h"], layer["w"], layer["c_in"])), jnp.float32)
    wt = jnp.asarray(rng.standard_normal(
        (layer["kh"], layer["kw"], layer["c_in"], layer["c_out"]))
        / (layer["kh"] * layer["kw"]), jnp.float32)
    kw = dict(kh=layer["kh"], kw=layer["kw"], c_out=layer["c_out"],
              stride=layer["stride"])
    t_im2col = time_jitted(
        functools.partial(_run_layer, algorithm="im2col", **kw), x, wt,
        warmup=warmup, iters=iters)
    t_wino = time_jitted(
        functools.partial(_run_layer, algorithm="winograd", **kw), x, wt,
        warmup=warmup, iters=iters)
    # plan/execute split: filter transform + geometry decided once at plan
    # time; steady-state apply() is the paper's deployment-path number.
    t0 = time.perf_counter()
    p = planlib.plan_conv2d(x.shape, wt, stride=layer["stride"],
                            algorithm="winograd")
    jax.block_until_ready(p.u)
    plan_build = time.perf_counter() - t0
    t_wino_planned = time_jitted(jax.jit(p.apply), x,
                                 warmup=warmup, iters=iters)
    return {"t_im2col_s": t_im2col, "t_winograd_s": t_wino,
            "t_winograd_planned_s": t_wino_planned,
            "plan_build_s": plan_build,
            "speedup": t_im2col / t_wino,
            "speedup_planned": t_im2col / t_wino_planned}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--networks", nargs="*", default=NETWORKS)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--max-layers-per-net", type=int, default=0,
                    help="0 = all unique suitable layers")
    ap.add_argument("--config", default="paper",
                    choices=["paper", "vgg_style", "vgg_style_quick"],
                    help="paper: Table-2 sweep over the five networks; "
                         "vgg_style[_quick]: streamed-vs-materialized "
                         "Pallas A/B on the VGG 3x3 stride-1 ladder")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    if args.config != "paper":
        rows, summary = run_vgg_style(args)
        if args.out:
            with open(args.out, "w") as f:
                json.dump({"config": args.config, "layers": rows,
                           "summary": summary}, f, indent=1)
        return summary

    rows = []
    seen = set()
    for net in args.networks:
        layers = [l for l in conv_layer_inventory(net) if l["suitable"]]
        uniq = []
        for l in layers:
            key = (l["kh"], l["kw"], l["c_in"], l["c_out"], l["h"], l["w"])
            if key not in seen:
                seen.add(key)
                uniq.append(l)
        if args.max_layers_per_net:
            uniq = uniq[:args.max_layers_per_net]
        for l in uniq:
            r = bench_layer(l, args.iters, args.warmup)
            r.update(net=net, layer=l["name"],
                     ltype=_layer_type(l["kh"], l["kw"]),
                     shape=f"{l['h']}x{l['w']}x{l['c_in']}->{l['c_out']}")
            rows.append(r)
            print(f"{net:13s} {l['name']:12s} {r['ltype']:4s} {r['shape']:22s} "
                  f"im2col={r['t_im2col_s']*1e3:8.2f}ms "
                  f"wino={r['t_winograd_s']*1e3:8.2f}ms "
                  f"planned={r['t_winograd_planned_s']*1e3:8.2f}ms "
                  f"(build {r['plan_build_s']*1e3:6.1f}ms) "
                  f"speedup={r['speedup']:.2f}x/"
                  f"{r['speedup_planned']:.2f}x", flush=True)

    # Table 2 rollup: (model, layer-type) -> avg / peak speedup, for both the
    # per-call path and the planned (pre-transformed weights) path
    groups = defaultdict(list)
    for r in rows:
        groups[(r["net"], r["ltype"])].append(
            (r["speedup"], r["speedup_planned"]))
    print("\n== Table 2 reproduction: per-layer speedup (im2row vs ours) ==")
    print(f"{'Model':14s} {'Layer-type':10s} {'Avg':>6s} {'Peak':>6s} "
          f"{'AvgPl':>6s} {'PeakPl':>6s} {'n':>3s}")
    summary = []
    for (net, lt), pairs in sorted(groups.items()):
        sp = [a for a, _ in pairs]
        spp = [b for _, b in pairs]
        row = {"net": net, "ltype": lt, "avg_speedup": float(np.mean(sp)),
               "peak_speedup": float(np.max(sp)),
               "avg_speedup_planned": float(np.mean(spp)),
               "peak_speedup_planned": float(np.max(spp)),
               "n_layers": len(sp)}
        summary.append(row)
        print(f"{net:14s} {lt:10s} {row['avg_speedup']:6.2f} "
              f"{row['peak_speedup']:6.2f} "
              f"{row['avg_speedup_planned']:6.2f} "
              f"{row['peak_speedup_planned']:6.2f} {len(sp):3d}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"layers": rows, "summary": summary}, f, indent=1)
    return summary


if __name__ == "__main__":
    main()
