"""Multi-device scaling curve for sharded NetworkPlan execution.

Measures compiled-plan apply() at 1/2/4/8 devices -- each device count in
a FRESH subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=N
(the way tests/test_multidevice.py runs) -- over two partitionings:

  * batch-sharded (partition="data"): a small VGG + MobileNet-v2 style
    ladder (dense conv, separable block, inverted residual, stride-2
    reduction) at a FIXED global batch -- strong scaling; weights
    replicate, the batch dim splits across the mesh.
  * halo-sharded (partition="spatial"): a stride-1 conv ladder at high
    resolution, H split across the mesh with ppermute halo exchange,
    gated on <= 1e-5 relative error against the unsharded oracle.

Normalization -- read this before comparing numbers: forced host devices
on a single physical core execute the shard_map program's per-shard work
SERIALLY, so wall-clock alone cannot show a speedup on this box. The
curve therefore reports raw wall seconds per apply AND the
serialized-forced-host-devices normalized throughput
    throughput(N) = N * global_batch / wall_N
which models N physical devices each doing its measured per-shard slice
concurrently. On real multi-core/multi-chip hardware wall_N itself drops;
here the signal is that per-shard partitioned work + collectives do not
blow up wall_N as N grows. The gates (strictly increasing throughput,
>= 3x aggregate at 8 devices) bound exactly that overhead:
speedup(8) >= 3 iff wall_8 <= (8/3) * wall_1.

The 8-device worker also round-trips the version-5 artifact: a warm
compile(artifact=, mesh=) must restore the recorded partition without
re-deciding (one artifact hit, zero misses, identical partition record).

  PYTHONPATH=src:. python -m benchmarks.scaling --out BENCH_PR9.json
  PYTHONPATH=src:. python -m benchmarks.scaling --quick --out ...   # CI
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

_MARK = "SCALING_JSON "
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _batch_ladder():
    from repro.models import cnn
    return [cnn.Conv("c1", 3, 3, 16),
            cnn.SeparableConv("sep1", 3, 24),
            cnn.InvertedResidual("ir1", 24, expand=2),
            cnn.Conv("c2", 3, 3, 32, stride=2),
            cnn.GlobalAvgPool(),
            cnn.Dense("fc", 10, relu=False)]


def _halo_ladder():
    from repro.models import cnn
    return [cnn.Conv("h1", 3, 3, 16),
            cnn.Conv("h2", 5, 5, 16),
            cnn.Conv("h3", 3, 3, 32),
            cnn.GlobalAvgPool(),
            cnn.Dense("fc", 10, relu=False)]


# ---------------------------------------------------------------------------
# worker: one device count, fresh process
# ---------------------------------------------------------------------------

def _time_apply(fn, x, *, warmup: int, iters: int) -> float:
    from benchmarks.common import time_jitted
    return time_jitted(fn, x, warmup=warmup, iters=iters)


def _worker(args) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import compile as C
    from repro.core.plan import clear_plan_cache, plan_cache_info
    from repro.launch.mesh import make_data_mesh
    from repro.models import cnn

    n = args.devices
    assert jax.device_count() >= n, (jax.device_count(), n)
    mesh = make_data_mesh(n)
    out: dict = {"devices": n}

    def sharded_callable(net):
        return net.apply if net.is_sharded() else jax.jit(net.apply)

    # -- batch-sharded, fixed global batch (strong scaling) -----------------
    g = args.global_batch
    specs = _batch_ladder()
    params = cnn.init_cnn(jax.random.key(0), specs, 3, res=args.res)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        (g, args.res, args.res, 3)).astype(np.float32))
    ref = np.asarray(jax.jit(
        C.compile(params, specs, res=args.res, batch=g).apply)(x))
    net = C.compile(params, specs, res=args.res, batch=g, mesh=mesh)
    fn = sharded_callable(net)
    y = np.asarray(fn(x))
    rel = float(np.max(np.abs(y - ref)) / np.max(np.abs(ref)))
    wall = _time_apply(fn, x, warmup=args.warmup, iters=args.iters)
    out["batch_sharded"] = {
        "num_shards": net.partition["num_shards"],
        "degraded": net.partition["degraded"],
        "global_batch": g, "res": args.res,
        "wall_s": wall, "rel_err": rel,
        "throughput_img_s": n * g / wall}

    # -- halo-sharded, high-resolution stride-1 ladder ----------------------
    hspecs = _halo_ladder()
    hparams = cnn.init_cnn(jax.random.key(1), hspecs, 3, res=args.halo_res)
    hx = jnp.asarray(np.random.default_rng(1).standard_normal(
        (args.halo_batch, args.halo_res, args.halo_res, 3))
        .astype(np.float32))
    href = np.asarray(jax.jit(
        C.compile(hparams, hspecs, res=args.halo_res,
                  batch=args.halo_batch).apply)(hx))
    hnet = C.compile(hparams, hspecs, res=args.halo_res,
                     batch=args.halo_batch, mesh=mesh, partition="spatial")
    hfn = sharded_callable(hnet)
    hy = np.asarray(hfn(hx))
    hrel = float(np.max(np.abs(hy - href)) / np.max(np.abs(href)))
    hwall = _time_apply(hfn, hx, warmup=args.warmup, iters=args.iters)
    out["halo_sharded"] = {
        "num_shards": hnet.partition["num_shards"],
        "degraded": hnet.partition["degraded"],
        "modes": hnet.partition.get("modes"),
        "batch": args.halo_batch, "res": args.halo_res,
        "wall_s": hwall, "rel_err": hrel,
        "throughput_img_s": n * args.halo_batch / hwall}

    # -- artifact round-trip: warm start restores the partition -------------
    if args.artifact:
        clear_plan_cache()
        cold = C.compile(params, specs, res=args.res, batch=g, mesh=mesh,
                         artifact=args.artifact)
        cold_info = plan_cache_info()
        clear_plan_cache()
        warm = C.compile(params, specs, res=args.res, batch=g, mesh=mesh,
                         artifact=args.artifact)
        info = plan_cache_info()
        wy = np.asarray(sharded_callable(warm)(x))
        out["warm_restore"] = {
            "cold_misses": cold_info["artifact_misses"],
            "warm_hits": info["artifact_hits"],
            "warm_misses": info["artifact_misses"],
            "partition_match": warm.partition == cold.partition,
            "rel_err": float(np.max(np.abs(wy - ref)) / np.max(np.abs(ref))),
        }
    return out


# ---------------------------------------------------------------------------
# parent: spawn one worker per device count, gate, emit the artifact
# ---------------------------------------------------------------------------

def _spawn(n: int, args, artifact: str | None) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.pathsep.join(
        [_ROOT, os.path.join(_ROOT, "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    cmd = [sys.executable, "-m", "benchmarks.scaling", "--worker",
           "--devices", str(n), "--global-batch", str(args.global_batch),
           "--res", str(args.res), "--halo-batch", str(args.halo_batch),
           "--halo-res", str(args.halo_res), "--iters", str(args.iters),
           "--warmup", str(args.warmup)]
    if artifact:
        cmd += ["--artifact", artifact]
    out = subprocess.run(cmd, capture_output=True, text=True, env=env,
                         timeout=1200)
    if out.returncode != 0:
        raise RuntimeError(f"scaling worker (devices={n}) failed:\n"
                           f"{out.stderr[-3000:]}")
    line = next(ln for ln in out.stdout.splitlines()
                if ln.startswith(_MARK))
    return json.loads(line[len(_MARK):])


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_PR9.json")
    ap.add_argument("--quick", action="store_true",
                    help="CI variant: fewer timing iters, smaller halo "
                         "resolution; the device counts and gates are "
                         "identical")
    ap.add_argument("--device-counts", type=int, nargs="*",
                    default=[1, 2, 4, 8])
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--res", type=int, default=32)
    ap.add_argument("--halo-batch", type=int, default=2)
    ap.add_argument("--halo-res", type=int, default=None,
                    help="default 64 (32 with --quick)")
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--warmup", type=int, default=None)
    ap.add_argument("--worker", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--devices", type=int, default=None,
                    help=argparse.SUPPRESS)
    ap.add_argument("--artifact", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args(argv)
    if args.halo_res is None:
        args.halo_res = 32 if args.quick else 64
    if args.iters is None:
        args.iters = 2 if args.quick else 5
    if args.warmup is None:
        args.warmup = 1 if args.quick else 2

    if args.worker:
        print(_MARK + json.dumps(_worker(args)), flush=True)
        return

    from benchmarks.common import bench_metadata

    t0 = time.time()
    art_dir = os.path.join(os.path.dirname(os.path.abspath(args.out)) or ".",
                           "results")
    os.makedirs(art_dir, exist_ok=True)
    art = os.path.join(art_dir, "scaling_plan_b8.npz")
    curve = []
    for n in args.device_counts:
        row = _spawn(n, args, art if n == max(args.device_counts) else None)
        b, h = row["batch_sharded"], row["halo_sharded"]
        print(f"devices={n}: batch wall {b['wall_s'] * 1e3:7.2f} ms  "
              f"thr {b['throughput_img_s']:8.1f} img/s  "
              f"rel {b['rel_err']:.2e} | halo wall "
              f"{h['wall_s'] * 1e3:7.2f} ms  rel {h['rel_err']:.2e}",
              flush=True)
        curve.append(row)

    thr = [r["batch_sharded"]["throughput_img_s"] for r in curve]
    warm = next((r["warm_restore"] for r in curve
                 if "warm_restore" in r), {})
    gates = {
        "batch_parity_1e5": all(
            r["batch_sharded"]["rel_err"] <= 1e-5 for r in curve),
        "halo_parity_1e5": all(
            r["halo_sharded"]["rel_err"] <= 1e-5 for r in curve),
        "throughput_strictly_increasing": all(
            b > a for a, b in zip(thr, thr[1:])),
        "speedup_max_dev_ge_3x": thr[-1] >= 3 * thr[0],
        "warm_restores_partition": bool(
            warm and warm["warm_hits"] == 1 and warm["warm_misses"] == 0
            and warm["partition_match"] and warm["rel_err"] <= 1e-5),
    }
    gates["all_pass"] = all(gates.values())
    report = {
        "benchmark": "sharded NetworkPlan scaling curve (PR 9)",
        "meta": bench_metadata(),
        "normalization": (
            "forced host devices on one physical core run shard_map "
            "per-shard work serially; throughput_img_s = devices * "
            "global_batch / wall_s models N physical devices running "
            "their measured per-shard slice concurrently. Raw wall_s is "
            "reported unmodified; the gates bound partitioning + "
            "collective overhead (speedup(8) >= 3x iff wall_8 <= 8/3 * "
            "wall_1), not physical parallel speedup on this box."),
        "config": {"device_counts": args.device_counts,
                   "global_batch": args.global_batch, "res": args.res,
                   "halo_batch": args.halo_batch,
                   "halo_res": args.halo_res, "iters": args.iters,
                   "warmup": args.warmup, "quick": args.quick},
        "curve": curve,
        "speedup_vs_1dev": [t / thr[0] for t in thr],
        "gates": gates,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    status = "PASS" if gates["all_pass"] else "FAIL"
    print(f"\n[{status}] gates: {gates}")
    print(f"wrote {args.out} in {time.time() - t0:.0f}s")
    if not gates["all_pass"]:
        sys.exit(1)


if __name__ == "__main__":
    main()
