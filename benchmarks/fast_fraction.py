"""Paper Fig. 3: runtime in Winograd-suitable ("fast") layers as a fraction
of the whole model, under both schemes.

Per-layer times come from timing each conv layer shape individually (batch 1)
under its scheme; suitable layers run ours-vs-im2row, unsuitable layers run
im2row in both configurations (exactly the paper's mixed policy)."""

from __future__ import annotations

import argparse
import functools
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch

from benchmarks.common import conv_layer_inventory, time_jitted
from benchmarks.per_layer import _run_layer

NETWORKS = ["vgg16", "vgg19", "googlenet", "inception_v3", "squeezenet"]


def bench(net: str, iters: int, warmup: int) -> dict:
    rng = np.random.default_rng(0)
    t_fast_im2row = t_fast_ours = t_rest = 0.0
    for l in conv_layer_inventory(net):
        groups = l.get("groups", 1)
        x = jnp.asarray(rng.standard_normal(
            (1, l["h"], l["w"], l["c_in"])), jnp.float32)
        w = jnp.asarray(rng.standard_normal(
            (l["kh"], l["kw"], l["c_in"] // groups, l["c_out"]))
            / (l["kh"] * l["kw"]), jnp.float32)
        kw = dict(kh=l["kh"], kw=l["kw"], c_out=l["c_out"],
                  stride=l["stride"], groups=groups)
        t_i = time_jitted(functools.partial(_run_layer, algorithm="im2col",
                                            **kw), x, w,
                          warmup=warmup, iters=iters)
        if l["suitable"]:
            t_fast_im2row += t_i
            t_fast_ours += time_jitted(
                functools.partial(_run_layer, algorithm="winograd", **kw),
                x, w, warmup=warmup, iters=iters)
        else:
            t_rest += t_i
    total_im2row = t_fast_im2row + t_rest
    total_ours = t_fast_ours + t_rest
    return {
        "network": net,
        "fast_fraction_im2row": t_fast_im2row / total_im2row,
        "fast_fraction_ours": t_fast_ours / total_ours,
        "t_fast_im2row_s": t_fast_im2row, "t_fast_ours_s": t_fast_ours,
        "t_rest_s": t_rest,
        "norm_runtime_ours": total_ours / total_im2row,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--networks", nargs="*", default=NETWORKS)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    rows = []
    print("== Fig 3 reproduction: fast-layer fraction of model runtime ==")
    print(f"{'Network':14s} {'fast% (im2row)':>15s} {'fast% (ours)':>13s} "
          f"{'norm runtime':>13s}")
    for net in args.networks:
        r = bench(net, args.iters, args.warmup)
        rows.append(r)
        print(f"{r['network']:14s} {100*r['fast_fraction_im2row']:14.1f}% "
              f"{100*r['fast_fraction_ours']:12.1f}% "
              f"{r['norm_runtime_ours']:13.3f}", flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
